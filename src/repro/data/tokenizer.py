"""Byte-level tokenizer (reserved specials + 256 bytes).

Vocab-agnostic: token ids above 255+n_special simply never occur, so any
model vocab >= 260 can consume these streams.
"""
from __future__ import annotations

from typing import List

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
SEP_ID = 3
N_SPECIAL = 4


def encode(text: str, *, bos: bool = True, eos: bool = False) -> List[int]:
    ids = [b + N_SPECIAL for b in text.encode("utf-8")]
    if bos:
        ids = [BOS_ID] + ids
    if eos:
        ids = ids + [EOS_ID]
    return ids


def decode(ids) -> str:
    bs = bytes(int(i) - N_SPECIAL for i in ids
               if N_SPECIAL <= int(i) < N_SPECIAL + 256)
    return bs.decode("utf-8", errors="replace")


def vocab_size() -> int:
    return 256 + N_SPECIAL
