"""Synthetic LM data pipeline: seeded document stream -> packed batches.

Deterministic, host-side (numpy), with a simple double-buffer prefetch.
Documents are pseudo-text with Zipfian word frequencies so the LM loss has
real structure to learn (tests assert the loss drops).
"""
from __future__ import annotations

import threading
import queue as _queue
from typing import Dict, Iterator, Optional

import numpy as np

from repro.data import tokenizer as tok


_WORDS = None


def _wordlist(n=2000, seed=7):
    global _WORDS
    if _WORDS is None:
        rng = np.random.default_rng(seed)
        syll = ["ka", "lo", "mi", "ra", "tu", "ve", "zo", "ne", "shi", "pa",
                "del", "gor", "an", "ex", "ul", "qui"]
        _WORDS = ["".join(rng.choice(syll, size=rng.integers(2, 5)))
                  for _ in range(n)]
    return _WORDS


def document_stream(seed: int) -> Iterator[str]:
    rng = np.random.default_rng(seed)
    words = _wordlist()
    ranks = np.arange(1, len(words) + 1)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)  # Zipf
    while True:
        n = int(rng.integers(20, 200))
        idx = rng.choice(len(words), size=n, p=probs)
        yield " ".join(words[i] for i in idx) + "."


def packed_batches(*, batch: int, seq_len: int, seed: int = 0,
                   vocab_limit: Optional[int] = None) -> Iterator[Dict[str, np.ndarray]]:
    """Yields {"tokens": [B,S], "labels": [B,S]} with next-token labels."""
    stream = document_stream(seed)
    buf: list = []
    need = batch * (seq_len + 1)
    while True:
        while len(buf) < need:
            buf.extend(tok.encode(next(stream), eos=True))
        flat = np.array(buf[:need], dtype=np.int32)
        buf = buf[need:]
        if vocab_limit:
            flat = np.minimum(flat, vocab_limit - 1)
        arr = flat.reshape(batch, seq_len + 1)
        yield {"tokens": arr[:, :-1], "labels": arr[:, 1:]}


class Prefetcher:
    """One-deep background prefetch over any iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: _queue.Queue = _queue.Queue(maxsize=depth)
        self._done = object()
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        try:
            for x in self._it:
                self._q.put(x)
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        x = self._q.get()
        if x is self._done:
            raise StopIteration
        return x
