"""Synthetic reasoning benchmarks + execution world model.

Stand-ins for GPQA / MMLU-Pro / AIME24 / LiveBench-Reasoning (and Math500
for router profiling), per DESIGN.md §3: each query carries a latent
ground-truth subtask DAG with per-subtask difficulty, token counts, and
dependencies. A seeded world model decides execution outcomes:

  * correctness: Bernoulli with p_exec(difficulty) per executor (edge is
    much weaker on hard subtasks), degraded multiplicatively by incorrect
    parents (noisy-AND propagation); common random numbers across paired
    executions so counterfactual credit assignment (paper App. C) is
    well-defined.
  * latency: rtt + tokens_out / throughput per executor.
  * API cost: cloud only, token-metered (GPT-4.1-like $/token scale so
    C_API lands on the paper's 1e-2 magnitude).

Difficulty distributions are calibrated so Edge-only / Cloud-only accuracy
on the GPQA stand-in approach the paper's Table 3 anchors (25.5 / 57.3).
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import numpy as np

ROLES = ("EXPLAIN", "ANALYZE", "GENERATE")

# difficulty-tier vocabulary: the subtask text carries learnable signal
_TIER_WORDS = [
    ("recall", "state", "list", "identify", "simple"),
    ("compare", "classify", "outline", "basic", "check"),
    ("derive", "compute", "analyze", "moderate", "estimate"),
    ("prove", "integrate", "multistep", "hard", "abstract"),
    ("novel", "research-grade", "expert", "intricate", "openended"),
]
_DOMAINS = {
    "gpqa": ["quantum", "organic", "genetics", "thermo", "astro"],
    "mmlu_pro": ["law", "economics", "physics", "history", "medicine"],
    "aime24": ["numbertheory", "geometry", "combinatorics", "algebra", "series"],
    "livebench_reasoning": ["logic", "puzzle", "deduction", "spatial", "sequence"],
    "math500": ["fraction", "polynomial", "trig", "limits", "matrix"],
}

# per-benchmark difficulty Beta(a,b) — tuned to the paper's accuracy anchors
_DIFFICULTY = {
    "gpqa": (3.2, 1.6),
    "mmlu_pro": (1.8, 2.0),
    "aime24": (5.0, 1.2),
    "livebench_reasoning": (2.2, 1.8),
    "math500": (2.0, 2.0),
}


@dataclass(frozen=True)
class Subtask:
    sid: int
    desc: str
    role: str                     # EXPLAIN | ANALYZE | GENERATE
    deps: Tuple[int, ...]
    difficulty: float             # latent, in [0,1]
    tok_in: int
    tok_out: int

    @property
    def requires(self) -> Tuple[str, ...]:
        return tuple(f"r{d}" for d in self.deps)

    @property
    def produces(self) -> Tuple[str, ...]:
        return (f"r{self.sid}",)


@dataclass(frozen=True)
class Query:
    qid: str
    benchmark: str
    text: str
    subtasks: Tuple[Subtask, ...]

    @property
    def n(self) -> int:
        return len(self.subtasks)


# --------------------------------------------------------------------------
# generation
# --------------------------------------------------------------------------

def _rng(*parts) -> np.random.Generator:
    h = hashlib.sha256("|".join(str(p) for p in parts).encode()).digest()
    return np.random.default_rng(int.from_bytes(h[:8], "little"))


def make_query(benchmark: str, idx: int, seed: int = 0,
               n_max: int = 7) -> Query:
    rng = _rng("query", benchmark, idx, seed)
    a, b = _DIFFICULTY[benchmark]
    domain = _DOMAINS[benchmark]
    # paper: 4-5 subtasks on average, <=7 (n_max cap)
    n = int(rng.choice(np.arange(3, n_max + 1),
                       p=[0.20, 0.35, 0.25, 0.15, 0.05][:n_max - 2]))
    base_d = float(rng.beta(a, b))

    subtasks: List[Subtask] = []
    for sid in range(n):
        if sid == 0:
            role = "EXPLAIN"
            deps: Tuple[int, ...] = ()
        elif sid == n - 1:
            role = "GENERATE"
            # GENERATE depends on a random nonempty subset of earlier nodes
            k = int(rng.integers(1, sid + 1))
            deps = tuple(sorted(rng.choice(sid, size=k, replace=False).tolist()))
        else:
            role = "ANALYZE"
            # each middle node depends on node 0 plus maybe others (DAG by
            # construction: deps < sid)
            extra = [d for d in range(1, sid) if rng.random() < 0.3]
            deps = tuple(sorted({0, *extra}))
        d = float(np.clip(base_d + rng.normal(0, 0.18) +
                          (0.12 if role == "ANALYZE" else -0.1), 0.02, 0.98))
        tier = min(int(d * len(_TIER_WORDS)), len(_TIER_WORDS) - 1)
        words = list(rng.choice(_TIER_WORDS[tier], size=3)) + \
            [str(rng.choice(domain))]
        tok_out = int(30 + 120 * d * rng.uniform(0.7, 1.3))
        tok_in = int(40 + 20 * len(deps) + 0.25 * tok_out)
        desc = (f"{role.capitalize()}: {' '.join(words)} step-{sid} "
                f"({'depends on ' + ','.join(map(str, deps)) if deps else 'root'})")
        subtasks.append(Subtask(sid, desc, role, deps, d, tok_in, tok_out))
    text = (f"[{benchmark}:{idx}] Solve the {domain[idx % len(domain)]} problem "
            f"requiring {n} steps of structured reasoning.")
    return Query(f"{benchmark}-{idx}", benchmark, text, tuple(subtasks))


def gen_benchmark(benchmark: str, n_queries: int, seed: int = 0) -> List[Query]:
    if benchmark not in _DIFFICULTY:
        raise KeyError(f"unknown benchmark {benchmark!r}")
    return [make_query(benchmark, i, seed) for i in range(n_queries)]


BENCHMARKS = tuple(_DIFFICULTY)


# --------------------------------------------------------------------------
# world model
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class ExecutorProfile:
    """Cost/quality profile of one executor (edge SLM or cloud LLM)."""

    name: str
    kind: str                     # "edge" | "cloud"
    # p(correct | difficulty) = clip(base - slope * d, floor, ceil)
    base: float
    slope: float
    floor: float = 0.02
    ceil: float = 0.99
    throughput_tps: float = 30.0  # decode tokens/sec
    prefill_tps: float = 2000.0
    rtt_s: float = 0.0            # network round-trip (cloud API)
    price_in: float = 0.0         # $ per token
    price_out: float = 0.0

    def p_correct(self, difficulty: float) -> float:
        return float(np.clip(self.base - self.slope * difficulty,
                             self.floor, self.ceil))

    def latency(self, tok_in: int, tok_out: int) -> float:
        return self.rtt_s + tok_in / self.prefill_tps + tok_out / self.throughput_tps

    def cost(self, tok_in: int, tok_out: int) -> float:
        return tok_in * self.price_in + tok_out * self.price_out


# Defaults calibrated to Table 3 anchors (edge 25.5%, cloud 57.3% on GPQA):
# grid-searched -> edge 25.8% / cloud 59.2% at parent_penalty=0.35
# (strong error propagation: matches the paper's evidence that early
# high-impact subtasks dominate final-answer correctness, Fig. 3)
EDGE_PROFILE = ExecutorProfile(
    name="edge-slm", kind="edge", base=0.99, slope=0.78, ceil=0.95,
    throughput_tps=45.0, prefill_tps=1500.0, rtt_s=0.02)
CLOUD_PROFILE = ExecutorProfile(
    name="cloud-llm", kind="cloud", base=0.98, slope=0.32, ceil=0.95,
    throughput_tps=35.0, prefill_tps=8000.0, rtt_s=1.2,
    price_in=8e-6, price_out=3.2e-5)

# App. D.2 model-pair swap (Qwen2.5-7B edge / DeepSeek-V3 cloud): stronger
# edge, cheaper but slower cloud.
SWAP_EDGE_PROFILE = ExecutorProfile(
    name="edge-7b", kind="edge", base=0.98, slope=0.92,
    throughput_tps=22.0, prefill_tps=1000.0, rtt_s=0.02)
SWAP_CLOUD_PROFILE = ExecutorProfile(
    name="cloud-dsv3", kind="cloud", base=1.03, slope=0.50,
    throughput_tps=25.0, prefill_tps=6000.0, rtt_s=0.9,
    price_in=0.27e-6, price_out=1.1e-6)


class WorldModel:
    """Seeded outcome model with common random numbers across routings."""

    def __init__(self, edge: ExecutorProfile = EDGE_PROFILE,
                 cloud: ExecutorProfile = CLOUD_PROFILE,
                 parent_penalty: float = 0.35, seed: int = 0):
        self.edge = edge
        self.cloud = cloud
        self.parent_penalty = parent_penalty  # p multiplier per wrong parent
        self.seed = seed

    def profile(self, r: int) -> ExecutorProfile:
        return self.cloud if r else self.edge

    def _u(self, query: Query, sid: int) -> float:
        """Common random number for subtask outcome (shared edge/cloud)."""
        return float(_rng("outcome", self.seed, query.qid, sid).random())

    def execute(self, query: Query, routing: Dict[int, int]
                ) -> Dict[int, bool]:
        """Correctness per subtask under a full routing (topological eval)."""
        correct: Dict[int, bool] = {}
        for st in query.subtasks:  # sids are topologically ordered
            p = self.profile(routing[st.sid]).p_correct(st.difficulty)
            n_bad = sum(not correct[d] for d in st.deps)
            p *= self.parent_penalty ** n_bad
            correct[st.sid] = self._u(query, st.sid) < p
        return correct

    def final_correct(self, query: Query, routing: Dict[int, int]) -> bool:
        return self.execute(query, routing)[query.subtasks[-1].sid]

    def exact_final_prob(self, query: Query, routing: Dict[int, int]) -> float:
        """Exact P(final correct) by dynamic programming over parent states.

        Exponential in max in-degree; n<=7 keeps this trivial.
        """
        probs: Dict[int, float] = {}
        # approximate: treat parent correctness as independent (true here
        # except for shared ancestors; acceptable since penalty is
        # multiplicative and deps are few)
        for st in query.subtasks:
            p_base = self.profile(routing[st.sid]).p_correct(st.difficulty)
            # E[penalty^n_bad] = prod_d (p_d + (1-p_d)*penalty)
            e_pen = 1.0
            for d in st.deps:
                e_pen *= probs[d] + (1 - probs[d]) * self.parent_penalty
            probs[st.sid] = p_base * e_pen
        return probs[query.subtasks[-1].sid]

    # ---- per-subtask costs ------------------------------------------
    def latency(self, st: Subtask, r: int) -> float:
        return self.profile(r).latency(st.tok_in, st.tok_out)

    def cost(self, st: Subtask, r: int) -> float:
        return self.profile(r).cost(st.tok_in, st.tok_out)

    def deltas(self, query: Query, st: Subtask,
               base_routing: Optional[Dict[int, int]] = None,
               n_contexts: int = 16) -> Tuple[float, float, float]:
        """(Δq, Δl, Δk) of moving ``st`` edge->cloud.

        Δq is the marginal effect of toggling subtask ``st`` averaged over
        sampled routing contexts for the *other* subtasks — the exact
        expectation of the paper's reuse-and-recombine estimator (App. C).
        Pass ``base_routing`` to pin the context instead.
        """
        sids = [s.sid for s in query.subtasks]
        if base_routing is not None:
            ctxs = [dict(base_routing)]
        else:
            rng = _rng("ctx", self.seed, query.qid, st.sid)
            ctxs = [dict(zip(sids, rng.integers(0, 2, size=len(sids))))
                    for _ in range(n_contexts)]
        dqs = []
        for ctx in ctxs:
            r1 = dict(ctx)
            r1[st.sid] = 1
            r0 = dict(ctx)
            r0[st.sid] = 0
            dqs.append(self.exact_final_prob(query, r1)
                       - self.exact_final_prob(query, r0))
        dq = float(np.mean(dqs))
        dl = self.latency(st, 1) - self.latency(st, 0)
        dk = self.cost(st, 1) - self.cost(st, 0)
        return dq, dl, dk
