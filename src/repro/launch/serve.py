"""Serving launcher: a HybridFlow edge/cloud deployment over two serving
engines driven by the concurrent multi-query runtime.

Queries are admitted together into ``ServingRuntime``: their ready
subtasks share the edge engine's KV slots and the cloud pool via the
fleet scheduler's async pump loop — every dispatch ``submit``s into a
real engine, the loop keeps stepping both engines while routing
continues, and co-scheduled subtasks decode in the same micro-batches
(batched chunked prefill + batched device-side sampling).
``--cloud-replicas R`` shards the cloud engine across an R-replica
``EnginePool`` (least-loaded dispatch, cloud concurrency = replicas x
slots); ``--no-pump`` forces the old synchronous per-subtask dispatch;
``--sequential`` restores the seed's one-query-at-a-time loop;
``--global-k-max`` caps fleet-wide API spend. Cross-request KV prefix
reuse is ON by default (sibling subtasks share their query's context
prefix; the final stats line reports hits and prefill tokens skipped)
— ``--no-prefix-reuse`` disables it, ``--prefix-block`` tunes the hash
granularity.

Open loop: ``--rps R`` generates a seeded Poisson arrival trace and
replays it with timed admission (``--trace FILE`` replays a recorded
``Trace`` JSON instead); the report then carries TTFT / queue-wait
percentiles at the measured offered RPS. ``--autoscale`` makes the
cloud pool elastic — occupancy-driven grow/shrink with a modeled cold
start, scale-to-zero on traffic gaps, poke-to-warm on the next
arrival. Example::

  PYTHONPATH=src python -m repro.launch.serve --rps 0.8 --duration 15 \
      --cloud-replicas 3 --autoscale

``--faults SPEC`` drives a chaos run: deterministic seeded fault
injection (cloud submit failures, stalls, replica crash/stragglers —
see ``serving.faults.FaultPlan.parse``) absorbed by scheduler-side
recovery (``--max-retries`` / ``--timeout`` / ``--backoff-base``) and
pool-side replica failover. Example::

  PYTHONPATH=src python -m repro.launch.serve --queries 12 \
      --cloud-replicas 2 --faults "submit_fail=0.1,crash=1@20,seed=0"

On TPU the cloud engine would run the large model on the production mesh;
on this container both engines run reduced configs on CPU (same code).

  PYTHONPATH=src python -m repro.launch.serve --queries 6
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import (ARCH_IDS, get_config, PAPER_EDGE_ARCH,
                           PAPER_CLOUD_ARCH)
from repro.core.hybridflow import HybridFlowPolicy
from repro.core.planner import SyntheticPlanner
from repro.core.profiler import train_default_router
from repro.core.exposure import mean_exposure
from repro.data.tasks import gen_benchmark, WorldModel
from repro.models import model as M
from repro.serving import (AutoscalePolicy, ServingConfig, ServingRuntime,
                           Trace)
from repro.serving.engine import ServingEngine, JAXExecutor


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--edge-arch", default=PAPER_EDGE_ARCH, choices=ARCH_IDS)
    ap.add_argument("--cloud-arch", default=PAPER_CLOUD_ARCH, choices=ARCH_IDS)
    ap.add_argument("--queries", type=int, default=6,
                    help="closed-loop batch size (open loop: trace decides)")
    ap.add_argument("--benchmark", default="gpqa")
    ap.add_argument("--tau0", type=float, default=0.35)
    ap.add_argument("--k-max", type=float, default=0.04)
    ap.add_argument("--max-inflight", type=int, default=8,
                    help="concurrently admitted queries (fleet admission)")
    ap.add_argument("--cloud-replicas", type=int, default=1,
                    help="shard the cloud engine across R pool replicas "
                         "(shared params, independent KV slot pools); "
                         "cloud concurrency becomes replicas x slots")
    ap.add_argument("--global-k-max", type=float, default=None,
                    help="fleet-wide API $ cap; forces edge when exhausted")
    ap.add_argument("--sequential", action="store_true",
                    help="seed-style one-query-at-a-time baseline")
    ap.add_argument("--no-pump", action="store_true",
                    help="synchronous per-subtask dispatch (pre-pump "
                         "baseline; engines never co-batch queries)")
    ap.add_argument("--prefill-chunk", type=int, default=64,
                    help="prefill chunk length (long prompts never stall "
                         "co-resident decodes)")
    ap.add_argument("--no-prefix-reuse", action="store_true",
                    help="disable cross-request KV prefix reuse (on by "
                         "default: shared block-aligned prompt prefixes "
                         "seed new slots instead of re-prefilling)")
    ap.add_argument("--prefix-block", type=int, default=None,
                    help="prefix-hash block size in tokens (default: "
                         "kvcache.PREFIX_BLOCK)")
    ap.add_argument("--calibrate", action="store_true",
                    help="enable the LinUCB calibration head")

    traffic = ap.add_argument_group(
        "open-loop traffic", "timed admission against an arrival trace; "
        "the report adds TTFT / queue-wait percentiles at measured RPS")
    traffic.add_argument("--rps", type=float, default=None,
                         help="offered load: seeded Poisson arrivals at "
                              "this rate (queries/s)")
    traffic.add_argument("--duration", type=float, default=15.0,
                         help="trace horizon in seconds (with --rps)")
    traffic.add_argument("--trace", default=None, metavar="PATH",
                         help="replay a recorded Trace JSON "
                              "(overrides --rps)")
    traffic.add_argument("--trace-seed", type=int, default=0,
                         help="arrival-sampling seed (with --rps)")

    elastic = ap.add_argument_group(
        "elastic cloud pool", "occupancy-driven autoscaling of the cloud "
        "EnginePool (use with --cloud-replicas R)")
    elastic.add_argument("--autoscale", action="store_true",
                         help="grow/shrink replicas from live occupancy "
                              "with a modeled cold start; scale-to-zero "
                              "on gaps, poke-to-warm on the next arrival")
    elastic.add_argument("--min-replicas", type=int, default=0,
                         help="floor kept warm (0 enables scale-to-zero)")
    elastic.add_argument("--idle-to-zero", type=float, default=1.0,
                         help="idle seconds before scaling to zero")

    chaos = ap.add_argument_group(
        "chaos / recovery", "seeded fault injection and the retry policy "
        "that absorbs it")
    chaos.add_argument("--faults", default=None, metavar="SPEC",
                       help="seeded chaos spec, e.g. "
                            "'submit_fail=0.1,stall=0.05@0.3,crash=1@20,"
                            "slow=0:4,seed=3' (see serving.faults)")
    chaos.add_argument("--max-retries", type=int, default=2,
                       help="attempts per side before a cloud subtask "
                            "degrades to the edge")
    chaos.add_argument("--timeout", type=float, default=None,
                       help="per-attempt deadline in seconds")
    chaos.add_argument("--backoff-base", type=float, default=0.05,
                       help="base of the capped exponential retry backoff")
    args = ap.parse_args()

    trace = None
    if args.trace is not None:
        trace = Trace.from_json(args.trace)
    elif args.rps is not None:
        trace = Trace.poisson(args.rps, args.duration, seed=args.trace_seed)
    if trace is not None and args.sequential:
        ap.error("--sequential is closed-loop; drop --rps/--trace")

    wm = WorldModel()
    edge_cfg = get_config(args.edge_arch).reduced()
    cloud_cfg = get_config(args.cloud_arch).reduced().variant(n_layers=2)
    from repro.models import kvcache as KV
    eng_kw = dict(max_len=192, prefill_chunk=args.prefill_chunk,
                  prefix_reuse=not args.no_prefix_reuse,
                  prefix_block=args.prefix_block or KV.PREFIX_BLOCK)
    edge_engine = ServingEngine(
        edge_cfg, M.init_params(edge_cfg, jax.random.PRNGKey(0),
                                dtype=jnp.float32),
        batch_slots=2, **eng_kw)
    cloud_engine = ServingEngine(
        cloud_cfg, M.init_params(cloud_cfg, jax.random.PRNGKey(1),
                                 dtype=jnp.float32),
        batch_slots=4, **eng_kw)
    edge = JAXExecutor(edge_engine, wm, cloud=False, concurrency=1)
    # concurrency derives from engine capacity; with --cloud-replicas the
    # runtime scales this executor out to an EnginePool (replicas x slots)
    cloud = JAXExecutor(cloud_engine, wm, cloud=True, price_out=3.2e-5)

    print("warm-starting router from offline profiling...")
    router, info = train_default_router(n_queries=120, epochs=60)
    calibrator = None
    if args.calibrate:
        from repro.core.bandit import LinUCBCalibrator
        calibrator = LinUCBCalibrator(dim=3)
    policy = HybridFlowPolicy(router, tau0=args.tau0, k_max=args.k_max,
                              calibrator=calibrator, wm=wm)
    retry = None
    if args.faults is not None or args.timeout is not None:
        from repro.core.scheduler import RetryPolicy
        retry = RetryPolicy(max_retries=args.max_retries,
                            backoff_base=args.backoff_base,
                            timeout_s=args.timeout)
    autoscale = None
    if args.autoscale:
        autoscale = AutoscalePolicy(min_replicas=args.min_replicas,
                                    idle_to_zero_s=args.idle_to_zero)
    config = ServingConfig(max_inflight=args.max_inflight,
                           global_k_max=args.global_k_max,
                           pump=False if args.no_pump else None,
                           replicas=args.cloud_replicas,
                           autoscale=autoscale,
                           retry=retry, faults=args.faults)
    runtime = ServingRuntime(edge, cloud, policy, planner=SyntheticPlanner(),
                             config=config)

    n_queries = trace.n if trace is not None else args.queries
    qs = gen_benchmark(args.benchmark, n_queries)
    t0 = time.time()
    if trace is not None:
        print(f"open loop: {trace.describe()}")
        report = runtime.serve_trace(trace, qs)
        mode = f"open-loop(max_inflight={args.max_inflight})"
    else:
        report = runtime.serve(
            qs, mode="sequential" if args.sequential else "fleet")
        mode = "sequential" if args.sequential else \
            (f"{'sync' if args.no_pump else 'pumped'}"
             f"(max_inflight={args.max_inflight})")
    for q, res in zip(qs, report.results):
        route = "".join("C" if res.offload[s] else "e"
                        for s in sorted(res.offload))
        print(f"  {q.qid:14s} {res.plan_status:8s} route={route:8s} "
              f"correct={res.final_correct} wall={res.latency:5.2f}s "
              f"api=${res.api_cost:.4f}")
    _, nbar = mean_exposure(report.results)
    print(f"\n[{mode}] {report.summary()} | exposure Ē={nbar:.2f} | "
          f"real {time.time()-t0:.1f}s")
    if report.stats.get("forced_edge"):
        print(f"global budget forced {report.stats['forced_edge']} "
              f"subtasks onto the edge")
    if trace is not None and "autoscale" in (report.trace or {}):
        a = report.trace["autoscale"]
        print(f"autoscale: ups={a['scale_ups']} downs={a['scale_downs']} "
              f"to_zero={a['scale_to_zero']} pokes={a['pokes']}")
        for t, action, i in a["events"]:
            print(f"  t={t:7.3f}s {action:8s} replica {i}")
    if args.faults is not None:
        s = report.stats
        print(f"chaos: injected={s.get('injected')} | recovery: "
              f"retries={s.get('retries', 0)} "
              f"timeouts={s.get('timeouts', 0)} "
              f"degraded={s.get('degraded', 0)} | pool: "
              f"deaths={s.get('cloud_deaths', 0)} "
              f"failovers={s.get('cloud_failovers', 0)} "
              f"hedges={s.get('cloud_hedges', 0)} "
              f"health={s.get('cloud_replica_health')}")
        n_ret = sum(r.n_retries for r in report.results)
        n_deg = sum(r.n_degraded for r in report.results)
        print(f"per-query recovery: {n_ret} retried attempts, "
              f"{n_deg} degraded subtasks, 0 failed queries")
    cloud_eng = runtime.cloud.engine     # EnginePool when replicas > 1
    hits = (edge_engine.stats["prefix_hits"]
            + cloud_eng.stats.get("prefix_hits", 0))
    saved = (edge_engine.stats["prefill_tokens_saved"]
             + cloud_eng.stats.get("prefill_tokens_saved", 0))
    if not args.no_prefix_reuse:
        print(f"prefix reuse: {hits} hits, {saved} prefill tokens skipped")
    print(f"edge: {edge_engine.stats} | cloud: {cloud_eng.stats}")
    if hasattr(cloud_eng, "occupancy"):
        for o in cloud_eng.occupancy():
            life = f" {o['lifecycle']}" if "lifecycle" in o else ""
            print(f"  cloud replica {o['replica']}:{life} "
                  f"requests={o['requests']} "
                  f"peak_active={o['peak_active']}/{o['slots']} "
                  f"slot_reuses={o['slot_reuses']}")


if __name__ == "__main__":
    main()
