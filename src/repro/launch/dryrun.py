import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                           + os.environ.get("REPRO_DRYRUN_DEVICES", "512"))

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes, with no device allocation (ShapeDtypeStruct
inputs). Proves the sharding config is coherent and extracts the roofline
terms (memory_analysis + cost_analysis + collective bytes from the
post-SPMD HLO).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh single --out benchmarks/artifacts/dryrun
  (REPRO_DRYRUN_DEVICES=8 + --mesh-shape 2x4 for CPU-cheap smoke runs)
"""
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.configs.base import SHAPES, InputShape, ModelConfig
from repro.launch import mesh as MESH
from repro.distributed import sharding as SH
from repro.models import model as M
from repro.training.optimizer import AdamWState
from repro.training.loop import TrainConfig, make_train_step


# --------------------------------------------------------------------------
# HLO collective accounting
# --------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-buffer bytes per collective op from post-SPMD HLO.

    Post-partitioning shapes are per-device, so the totals approximate the
    per-chip bytes entering the interconnect (ring all-gather/reduce move
    ~2(n-1)/n x this; we report the raw buffer totals and keep the factor
    out of the roofline term — documented in EXPERIMENTS.md).
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        for op in _COLLECTIVES:
            if f" {op}(" in line or f" {op}-start(" in line:
                head = line.split(f" {op}", 1)[0]
                rhs = head.split("=", 1)[-1]
                for dt, dims in _SHAPE_RE.findall(rhs):
                    if dt in _DTYPE_BYTES:
                        out[op] += _shape_bytes(dt, dims)
                break
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def _mem_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = float(v)
    out["total_bytes"] = (out.get("argument_size_in_bytes", 0)
                          + out.get("output_size_in_bytes", 0)
                          + out.get("temp_size_in_bytes", 0)
                          - out.get("alias_size_in_bytes", 0))
    return out


def _cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


# --------------------------------------------------------------------------
# per-combination lowering
# --------------------------------------------------------------------------

def _abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: M.init_params(cfg, jax.random.PRNGKey(0)))


def _abstract_opt(params):
    dt = jnp.bfloat16 if _FLAGS["opt_bf16"] else jnp.float32
    def mk():
        zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, dt), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros, nu=zeros)
    return jax.eval_shape(mk)


def lower_combo(cfg: ModelConfig, shape: InputShape, mesh, *,
                remat: bool = False, remat_policy: Optional[str] = None,
                donate: bool = True, strategy: str = "tp"):
    """Returns (lowered, in_shardings_info). Raises on sharding errors."""
    from repro.distributed.context import set_mesh
    set_mesh(mesh)  # shard_map layers (ep MoE) read the ambient mesh
    params = _abstract_params(cfg)
    p_sh = SH.param_shardings(cfg, params, mesh, strategy=strategy)

    if shape.kind == "train":
        specs = M.input_specs(cfg, shape)
        b_sh = SH.batch_shardings(cfg, specs, mesh)
        opt = _abstract_opt(params)
        o_sh = AdamWState(step=SH.replicated(mesh),
                          mu=jax.tree.map(lambda s: s, p_sh),
                          nu=jax.tree.map(lambda s: s, p_sh))
        tcfg = TrainConfig(remat=remat, remat_policy=remat_policy)
        step = make_train_step(cfg, tcfg)
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         donate_argnums=(0, 1) if donate else ())
        with mesh:
            return jitted.lower(params, opt, specs)

    if shape.kind == "prefill":
        specs = M.input_specs(cfg, shape)
        b_sh = SH.batch_shardings(cfg, specs["batch"], mesh)
        c_sh = SH.cache_shardings(cfg, specs["cache"], mesh)

        def prefill(p, b, c):
            return M.serve_prefill(p, cfg, b, c)

        jitted = jax.jit(prefill, in_shardings=(p_sh, b_sh, c_sh),
                         donate_argnums=(2,) if donate else ())
        with mesh:
            return jitted.lower(params, specs["batch"], specs["cache"])

    # decode
    cache_dt = jnp.float8_e4m3fn if _FLAGS["kv_f8"] else jnp.bfloat16
    specs = M.input_specs(cfg, shape, cache_dtype=cache_dt)
    t_sh, pos_sh = SH.token_shardings(shape.global_batch, mesh)
    c_sh = SH.cache_shardings(cfg, specs["cache"], mesh)

    def decode(p, t, pos, c):
        return M.serve_decode(p, cfg, t, pos, c)

    jitted = jax.jit(decode, in_shardings=(p_sh, t_sh, pos_sh, c_sh),
                     donate_argnums=(3,) if donate else ())
    with mesh:
        return jitted.lower(params, specs["token"], specs["pos"],
                            specs["cache"])


def applicable(cfg: ModelConfig, shape: InputShape) -> bool:
    """long_500k only for sub-quadratic archs (DESIGN.md §4)."""
    if shape.name == "long_500k":
        return cfg.supports_long_context
    return True


def _depth_variants(cfg: ModelConfig):
    """Two shallow copies of the config for per-layer cost extrapolation.

    XLA's cost_analysis counts a while-loop (lax.scan) body ONCE, so the
    full-depth module under-reports flops/bytes/collectives by ~L×. We
    lower the same (shape, mesh) at two shallow depths and extrapolate
    linearly: cost(L) = c_a + (c_b - c_a) · (L - L_a)/(L_b - L_a).
    Depths respect each family's block-group granularity.
    """
    if cfg.family == "hybrid":
        a, b = cfg.attn_every, 2 * cfg.attn_every
    elif cfg.family == "ssm":
        g = cfg.mlstm_per_slstm + 1
        a, b = g, 2 * g
    else:
        a, b = 2, 4
    kw_a, kw_b = {"n_layers": a}, {"n_layers": b}
    if cfg.is_encoder_decoder:
        kw_a["n_encoder_layers"] = a
        kw_b["n_encoder_layers"] = b
    return (cfg.variant(**kw_a), a), (cfg.variant(**kw_b), b)


def _extrapolate(v_a: float, v_b: float, la: int, lb: int, L: int) -> float:
    return v_a + (v_b - v_a) * (L - la) / (lb - la)


def model_flops(cfg: ModelConfig, shape: InputShape) -> float:
    """Analytic MODEL_FLOPS: 6·N·D train, 2·N·D forward (N = active params)."""
    n = cfg.param_count(active_only=True)
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per request


# §Perf optimization bundles selectable via --opt (comma-separated)
_OPTS = {
    "blocked_attn": dict(attention_block_q=512),
    "blocked_attn_2k": dict(attention_block_q=2048),
    "carry_cache": dict(carry_cache=True),
    "shard_seq": dict(shard_attn_seq=True),
    "ep_moe": dict(moe_impl="ep"),
    "expand_kv": "EXPAND_KV",     # resolved per-config (needs mesh size)
    "fsdp": "FSDP",               # strategy, not a config field
    "opt_bf16": "OPT_BF16",       # bf16 Adam moments (halves optimizer HBM)
    "kv_f8": "KV_F8",             # fp8(e4m3) KV cache (halves cache reads)
}

_FLAGS = {"opt_bf16": False, "kv_f8": False}


def apply_opts(cfg: ModelConfig, opts) -> tuple:
    """Returns (cfg, strategy) with the requested §Perf knobs applied."""
    strategy = "tp"
    kw = {}
    _FLAGS["opt_bf16"] = False
    _FLAGS["kv_f8"] = False
    for o in opts or ():
        v = _OPTS[o]
        if v == "FSDP":
            strategy = "fsdp"
        elif v == "OPT_BF16":
            _FLAGS["opt_bf16"] = True
        elif v == "KV_F8":
            _FLAGS["kv_f8"] = True
        elif v == "EXPAND_KV":
            if cfg.uses_attention and cfg.n_heads % 16 == 0 \
                    and cfg.n_kv_heads < 16 and 16 % cfg.n_kv_heads == 0:
                kw["kv_cache_expand_heads"] = 16
        else:
            kw.update(v)
    return (cfg.variant(**kw) if kw else cfg), strategy


def run_combo(arch: str, shape_name: str, *, multi_pod: bool,
              mesh_shape: Optional[tuple] = None,
              remat: Optional[bool] = None, remat_policy: Optional[str] = None,
              extrapolate: bool = True, opts=()) -> Dict[str, Any]:
    cfg = get_config(arch)
    cfg, strategy = apply_opts(cfg, opts)
    shape = SHAPES[shape_name]
    if remat is None:
        remat = shape.kind == "train"  # full activation remat is the
        #                                baseline policy for training
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "family": cfg.family,
        "params": cfg.param_count(),
        "active_params": cfg.param_count(active_only=True),
        "model_flops": model_flops(cfg, shape),
        "remat": remat, "remat_policy": remat_policy,
        "opts": list(opts), "strategy": strategy,
    }
    if not applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "full attention at 524k is quadratic; no SWA variant"
        return rec
    if mesh_shape is not None:
        axes = ("pod", "data", "model")[-len(mesh_shape):]
        mesh = jax.make_mesh(mesh_shape, axes)
    else:
        mesh = MESH.make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    lowered = lower_combo(cfg, shape, mesh, remat=remat,
                          remat_policy=remat_policy, strategy=strategy)
    rec["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)
    rec["memory"] = _mem_dict(compiled)
    rec["cost"] = _cost_dict(compiled)
    rec["collectives"] = collective_bytes(compiled.as_text())
    rec["n_devices"] = int(mesh.devices.size)

    if extrapolate:
        from repro.models import transformer as T
        try:
            (cfg_a, la), (cfg_b, lb) = _depth_variants(cfg)
            recs = []
            T.set_scan_unroll(True)  # exact per-layer costs (see layer_scan)
            for cv, lv in ((cfg_a, la), (cfg_b, lb)):
                cl = lower_combo(cv, shape, mesh, remat=remat,
                                 remat_policy=remat_policy,
                                 strategy=strategy).compile()
                recs.append({"n_layers": lv, "cost": _cost_dict(cl),
                             "collectives": collective_bytes(cl.as_text())})
            L = cfg.n_layers
            rec["depth_variants"] = recs
            rec["cost_extrapolated"] = {
                k: _extrapolate(recs[0]["cost"][k], recs[1]["cost"][k],
                                la, lb, L)
                for k in recs[0]["cost"]}
            rec["collectives_extrapolated"] = {
                k: _extrapolate(recs[0]["collectives"][k],
                                recs[1]["collectives"][k], la, lb, L)
                for k in recs[0]["collectives"]}
        except Exception as e:  # extrapolation is best-effort
            rec["extrapolation_error"] = repr(e)
        finally:
            T.set_scan_unroll(False)

    rec["status"] = "ok"
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--mesh-shape", default=None,
                    help="override, e.g. 2x4 (smoke tests)")
    ap.add_argument("--out", default="benchmarks/artifacts/dryrun")
    ap.add_argument("--remat", default="auto", choices=["auto", "on", "off"])
    ap.add_argument("--remat-policy", default=None)
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--opt", default="",
                    help="comma-separated §Perf knobs: " + ",".join(_OPTS))
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)
    remat = {"auto": None, "on": True, "off": False}[args.remat]

    archs = ARCH_IDS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]
    mesh_shape = (tuple(int(x) for x in args.mesh_shape.split("x"))
                  if args.mesh_shape else None)
    os.makedirs(args.out, exist_ok=True)

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}__{shape}__{'multi' if mp else 'single'}"
                if args.tag:
                    tag += f"__{args.tag}"
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                try:
                    rec = run_combo(arch, shape, multi_pod=mp,
                                    mesh_shape=mesh_shape, remat=remat,
                                    remat_policy=args.remat_policy,
                                    extrapolate=not args.no_extrapolate,
                                    opts=[o for o in args.opt.split(",") if o])
                except Exception as e:  # noqa
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "multi" if mp else "single",
                           "status": "error", "error": repr(e),
                           "trace": traceback.format_exc()[-2000:]}
                    n_fail += 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                st = rec["status"]
                extra = ""
                if st == "ok":
                    gb = rec["memory"]["total_bytes"] / 2**30
                    extra = (f" mem/dev={gb:.2f}GiB flops={rec['cost']['flops']:.3e}"
                             f" coll={rec['collectives']['total']/2**20:.1f}MiB"
                             f" ({rec['lower_s']}+{rec['compile_s']}s)")
                elif st == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{st}] {tag}{extra}", flush=True)
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
