"""Production mesh definitions (TPU v5e target).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model") — the
"pod" axis carries pure data parallelism across pods (gradient all-reduce
over DCI), "model" stays intra-pod ICI.

Functions, not module constants: importing this module never touches jax
device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple:
    """Mesh axes carrying the batch dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis_size(mesh) -> int:
    return mesh.shape["model"]


def data_axis_size(mesh) -> int:
    import math
    return math.prod(mesh.shape[a] for a in data_axes(mesh))


# Hardware constants (TPU v5e), used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link
