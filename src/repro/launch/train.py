"""Distributed training launcher.

On a TPU slice this builds the production mesh, shards params/optimizer
per repro.distributed.sharding (TP or FSDP), and runs the jitted train
step over the synthetic data pipeline. On this CPU container it runs the
same code path on a 1x1 mesh with a reduced config (smoke: --smoke).

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-1.5b --smoke \
      --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_config
from repro.data.pipeline import packed_batches, Prefetcher
from repro.distributed import sharding as SH
from repro.distributed.context import mesh_context
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.training.loop import TrainConfig, make_train_step
from repro.training.optimizer import AdamWConfig, adamw_init
from repro.training import checkpoint as CKPT


def build_mesh(args):
    if args.smoke:
        return jax.make_mesh((1, 1), ("data", "model"))
    return make_production_mesh(multi_pod=args.multi_pod)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--strategy", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config on a 1x1 mesh (CPU)")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.reduced()
    mesh = build_mesh(args)
    dtype = jnp.float32 if args.smoke else jnp.bfloat16

    with mesh_context(mesh), mesh:
        params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=dtype)
        p_sh = SH.param_shardings(cfg, params, mesh, strategy=args.strategy)
        params = jax.device_put(params, p_sh)
        opt = jax.device_put(adamw_init(params), jax.tree.map(
            lambda s: s, _opt_shardings(p_sh, mesh)))
        tcfg = TrainConfig(opt=AdamWConfig(lr=args.lr, warmup_steps=10,
                                           total_steps=args.steps),
                           remat=args.remat)
        step = jax.jit(make_train_step(cfg, tcfg), donate_argnums=(0, 1))

        data = packed_batches(batch=args.batch, seq_len=args.seq, seed=0,
                              vocab_limit=cfg.vocab_size)
        data = Prefetcher({k: jnp.asarray(v) for k, v in b.items()}
                          for b in data)
        t0 = time.time()
        for i in range(args.steps):
            batch = next(data)
            params, opt, metrics = step(params, opt, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
        if args.ckpt_dir:
            CKPT.save_checkpoint(f"{args.ckpt_dir}/ckpt_{args.steps}",
                                 {"params": params, "opt": opt},
                                 step=args.steps)
            print(f"checkpoint -> {args.ckpt_dir}")


def _opt_shardings(p_sh, mesh):
    from repro.training.optimizer import AdamWState
    from repro.distributed.sharding import replicated
    return AdamWState(step=replicated(mesh), mu=p_sh, nu=p_sh)


if __name__ == "__main__":
    main()
