"""Public model API: family dispatch + input specs for the dry-run.

Every architecture exposes the same four entry points:
  init_params(cfg, key)                     -> params pytree
  forward(params, cfg, batch)               -> (logits, aux_loss)
  serve_prefill(params, cfg, batch, cache)  -> (last logits, cache)
  serve_decode(params, cfg, token, pos, cache) -> (logits, cache)

``input_specs(cfg, shape)`` returns jax.ShapeDtypeStruct stand-ins for
every model input of the given benchmark shape (no device allocation) —
this is what launch/dryrun.py lowers against.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, InputShape, SHAPES
from repro.models import layers as L
from repro.models import transformer as T
from repro.models import kvcache as KV


# --------------------------------------------------------------------------
# init / forward dispatch
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key, *, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    if cfg.family in ("dense", "moe", "vlm"):
        return T.init_decoder_model(key, cfg, dtype=dtype)
    if cfg.family == "audio":
        return T.init_encdec_model(key, cfg, dtype=dtype)
    if cfg.family == "hybrid":
        return T.init_hybrid_model(key, cfg, dtype=dtype)
    if cfg.family == "ssm":
        return T.init_xlstm_model(key, cfg, dtype=dtype)
    raise ValueError(cfg.family)


def forward(params, cfg: ModelConfig, batch: Dict[str, Any], *,
            remat: bool = False, remat_policy: Optional[str] = None):
    if cfg.family in ("dense", "moe", "vlm"):
        return T.decoder_forward(params, cfg, batch, remat=remat,
                                 remat_policy=remat_policy)
    if cfg.family == "audio":
        return T.encdec_forward(params, cfg, batch)
    if cfg.family == "hybrid":
        return T.hybrid_forward(params, cfg, batch, remat=remat,
                                remat_policy=remat_policy)
    if cfg.family == "ssm":
        return T.xlstm_forward(params, cfg, batch)
    raise ValueError(cfg.family)


def loss_fn(params, cfg: ModelConfig, batch, **kw):
    logits, aux = forward(params, cfg, batch, **kw)
    ce = L.softmax_cross_entropy(logits, batch["labels"])
    return ce + aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------------
# serving dispatch
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, *, dtype=jnp.bfloat16):
    if cfg.family in ("dense", "moe", "vlm"):
        return KV.init_attn_cache(cfg, batch, max_len, cfg.n_layers, dtype)
    if cfg.family == "audio":
        c = KV.init_attn_cache(cfg, batch, max_len, cfg.n_layers, dtype)
        hd = cfg.resolved_head_dim
        c["xk"] = jnp.zeros((cfg.n_layers, batch, cfg.encoder_seq,
                             cfg.n_kv_heads, hd), dtype)
        c["xv"] = jnp.zeros_like(c["xk"])
        return c
    if cfg.family == "hybrid":
        return T.hybrid_init_cache(cfg, batch, max_len, dtype)
    if cfg.family == "ssm":
        # recurrent numerics stay f32; the sLSTM hidden state rides in the
        # activation dtype so the layer-scan carry dtype is stable
        return T.xlstm_init_cache(cfg, batch, 0, dtype)
    raise ValueError(cfg.family)


def serve_prefill(params, cfg: ModelConfig, batch, cache):
    if cfg.family in ("dense", "moe", "vlm"):
        return T.decoder_prefill(params, cfg, batch, cache)
    if cfg.family == "audio":
        return T.encdec_prefill(params, cfg, batch, cache)
    if cfg.family == "hybrid":
        return T.hybrid_prefill(params, cfg, batch, cache)
    if cfg.family == "ssm":
        return T.xlstm_prefill(params, cfg, batch, cache)
    raise ValueError(cfg.family)


# Families whose prompts can be prefilled as padded/ragged chunked batches
# written straight into the slot-pooled cache. Attention-only decoders
# qualify: causal masking keeps padded/garbage lines out of every valid
# query. MoE is excluded (expert-capacity competition couples batch rows,
# so batched outputs would not be token-identical to batch-1); recurrent
# families (ssm/hybrid) and encoder-decoder/vlm prefixes carry state that
# padding would corrupt — they use the engine's legacy per-slot path.
CHUNKED_PREFILL_FAMILIES = ("dense",)


def serve_prefill_chunk(params, cfg: ModelConfig, tokens, cache, slot_idx,
                        pos0, take, kv_width=None):
    """Batched ragged chunk prefill into the slot-pooled serving cache.

    tokens [G, S] right-padded ids; slot_idx/pos0/take [G]; ``kv_width``
    statically bounds how many cache lines attention reads — see
    ``transformer.decoder_prefill_chunk``. Only families in
    ``CHUNKED_PREFILL_FAMILIES`` support this path.
    """
    if cfg.family in CHUNKED_PREFILL_FAMILIES:
        return T.decoder_prefill_chunk(params, cfg, tokens, cache, slot_idx,
                                       pos0, take, kv_width=kv_width)
    raise NotImplementedError(
        f"chunked slot prefill is not supported for family {cfg.family!r}")


def serve_decode(params, cfg: ModelConfig, token, pos, cache):
    if cfg.family in ("dense", "moe", "vlm"):
        return T.decoder_decode(params, cfg, token, pos, cache)
    if cfg.family == "audio":
        return T.encdec_decode(params, cfg, token, pos, cache)
    if cfg.family == "hybrid":
        return T.hybrid_decode(params, cfg, token, pos, cache)
    if cfg.family == "ssm":
        return T.xlstm_decode(params, cfg, token, pos, cache)
    raise ValueError(cfg.family)


# convenience aliases used by launch/
def train_step_fn(cfg):  # resolved in training.loop to avoid import cycle
    from repro.training.loop import make_train_step
    return make_train_step(cfg)


def serve_prefill_fn(cfg):
    def fn(params, batch, cache):
        return serve_prefill(params, cfg, batch, cache)
    return fn


def serve_decode_fn(cfg):
    def fn(params, token, pos, cache):
        return serve_decode(params, cfg, token, pos, cache)
    return fn


def build_model(cfg: ModelConfig):
    """Bundle of bound functions for one architecture."""
    return {
        "config": cfg,
        "init": lambda key, dtype=None: init_params(cfg, key, dtype=dtype),
        "forward": lambda p, b, **kw: forward(p, cfg, b, **kw),
        "loss": lambda p, b, **kw: loss_fn(p, cfg, b, **kw),
        "init_cache": lambda b, m, dtype=jnp.bfloat16: init_cache(cfg, b, m, dtype=dtype),
        "prefill": lambda p, b, c: serve_prefill(p, cfg, b, c),
        "decode": lambda p, t, pos, c: serve_decode(p, cfg, t, pos, c),
    }


# --------------------------------------------------------------------------
# input specs (dry-run stand-ins, ShapeDtypeStruct only)
# --------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: InputShape | str,
                *, cache_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Abstract inputs for (cfg, shape). For train/prefill: the batch dict.
    For decode: {"token","pos","cache"} with a cache representing a
    prefilled context of shape.seq_len tokens."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, Sq = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    def batch_specs(seq):
        b: Dict[str, Any] = {"tokens": _sds((B, seq), i32)}
        if cfg.family == "vlm":
            b["patch_embeds"] = _sds((B, cfg.n_image_patches, cfg.d_model),
                                     jnp.bfloat16)
            b["tokens"] = _sds((B, seq - cfg.n_image_patches), i32)
        if cfg.family == "audio":
            b["frames"] = _sds((B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return b

    if shape.kind == "train":
        b = batch_specs(Sq)
        lab_seq = b["tokens"].shape[1]
        b["labels"] = _sds((B, lab_seq), i32)
        return b

    if shape.kind == "prefill":
        b = batch_specs(Sq)
        max_len = KV.cache_len(cfg, Sq)
        cache = init_cache_specs(cfg, B, max_len, cache_dtype)
        return {"batch": b, "cache": cache}

    # decode: one new token against a context of Sq tokens
    max_len = KV.cache_len(cfg, Sq)
    return {
        "token": _sds((B, 1), i32),
        "pos": _sds((B,), i32),
        "cache": init_cache_specs(cfg, B, max_len, cache_dtype),
    }


def init_cache_specs(cfg, batch, max_len, dtype):
    """ShapeDtypeStruct mirror of init_cache (no allocation)."""
    concrete = jax.eval_shape(
        lambda: init_cache(cfg, batch, max_len, dtype=dtype))
    return concrete
