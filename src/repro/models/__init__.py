__all__ = [
    "build_model", "init_params", "forward", "train_step_fn",
    "serve_prefill_fn", "serve_decode_fn", "input_specs", "init_cache",
]

from repro.models.model import (
    build_model,
    init_params,
    forward,
    train_step_fn,
    serve_prefill_fn,
    serve_decode_fn,
    input_specs,
    init_cache,
)
