"""Chunked gated linear recurrence — shared engine for Mamba2 (SSD) and mLSTM.

Computes, for per-(batch, head) scalar decay gates a_t ∈ (0,1]:

    S_t = a_t · S_{t-1} + k_t v_tᵀ          (state  [Dk, Dv])
    y_t = q_t · S_t                          (output [Dv])

in O(T·Dk·Dv) with chunked parallelism (the SSD / GLA algorithm):
within a chunk of length C the quadratic "attention" form is used
(L-masked q·kᵀ), across chunks the state is carried by a lax.scan.
This is the TPU-native adaptation: intra-chunk work is MXU matmuls with
C=chunk multiples of 128; the sequential dimension is T/C, not T.

Shapes: q,k [B,T,H,Dk], v [B,T,H,Dv], log_a [B,T,H] (log decay, <= 0).
Returns y [B,T,H,Dv] and final state [B,H,Dk,Dv].
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def chunked_gla(q, k, v, log_a, *, chunk: int = 128,
                initial_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    C = min(chunk, T)
    if T % C:
        pad = C - T % C
        zq = jnp.zeros((B, pad, H, Dk), q.dtype)
        zv = jnp.zeros((B, pad, H, Dv), v.dtype)
        q = jnp.concatenate([q, zq], axis=1)
        k = jnp.concatenate([k, zq], axis=1)
        v = jnp.concatenate([v, zv], axis=1)
        log_a = jnp.concatenate([log_a, jnp.zeros((B, pad, H), log_a.dtype)], axis=1)
        Tp = T + pad
    else:
        Tp = T
    NC = Tp // C

    # reshape to chunks: [B, NC, C, H, *]
    qc = q.reshape(B, NC, C, H, Dk)
    kc = k.reshape(B, NC, C, H, Dk)
    vc = v.reshape(B, NC, C, H, Dv)
    la = log_a.reshape(B, NC, C, H).astype(jnp.float32)

    cum = jnp.cumsum(la, axis=2)              # within-chunk cumulative log decay
    total = cum[:, :, -1]                      # [B,NC,H] full-chunk log decay

    # Intra-chunk: y_intra[i] = sum_{j<=i} (prod_{j<k<=i} a_k) (q_i·k_j) v_j
    #   decay(i,j) = exp(cum[i]-cum[j]) for j<=i (gate of token j itself is
    #   applied to the *previous* state, so k_j enters undccayed at step j).
    di = cum[:, :, :, None, :]                 # [B,NC,C,1,H] (i)
    dj = cum[:, :, None, :, :]                 # [B,NC,1,C,H] (j)
    idx = jnp.arange(C)
    tri = idx[:, None] >= idx[None, :]         # i >= j
    decay = jnp.where(tri[None, None, :, :, None], jnp.exp(di - dj), 0.0)
    qk = jnp.einsum("bnihd,bnjhd->bnijh", qc.astype(jnp.float32),
                    kc.astype(jnp.float32))
    att = qk * decay
    y_intra = jnp.einsum("bnijh,bnjhd->bnihd", att, vc.astype(jnp.float32))

    # Chunk summaries: state contribution of each chunk (decayed to chunk end)
    #   S_chunk = sum_j exp(total - cum[j]) k_j v_jᵀ
    kdec = kc.astype(jnp.float32) * jnp.exp(total[:, :, None] - cum)[..., None]
    s_chunk = jnp.einsum("bnjhd,bnjhe->bnhde", kdec, vc.astype(jnp.float32))

    # Scan chunk states: S_n = exp(total_n) S_{n-1} + s_chunk_n
    if initial_state is None:
        s0 = jnp.zeros((B, H, Dk, Dv), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    def step(s, xs):
        tot, sc = xs            # tot [B,H], sc [B,H,Dk,Dv]
        s_new = jnp.exp(tot)[..., None, None] * s + sc
        return s_new, s        # emit state *entering* the chunk

    tot_sw = jnp.moveaxis(total, 1, 0)         # [NC,B,H]
    sc_sw = jnp.moveaxis(s_chunk, 1, 0)        # [NC,B,H,Dk,Dv]
    s_final, s_prev = jax.lax.scan(step, s0, (tot_sw, sc_sw))
    s_prev = jnp.moveaxis(s_prev, 0, 1)        # [B,NC,H,Dk,Dv]

    # Inter-chunk: y_inter[i] = exp(cum[i]) q_i · S_prev
    qdec = qc.astype(jnp.float32) * jnp.exp(cum)[..., None]
    y_inter = jnp.einsum("bnihd,bnhde->bnihe", qdec, s_prev)

    y = (y_intra + y_inter).reshape(B, Tp, H, Dv)[:, :T]
    return y.astype(v.dtype), s_final


def gla_reference(q, k, v, log_a, *, initial_state=None):
    """Sequential oracle for chunked_gla (tests)."""
    B, T, H, Dk = q.shape
    Dv = v.shape[-1]
    s = (jnp.zeros((B, H, Dk, Dv), jnp.float32) if initial_state is None
         else initial_state.astype(jnp.float32))
    ys = []
    for t in range(T):
        a = jnp.exp(log_a[:, t].astype(jnp.float32))        # [B,H]
        s = a[..., None, None] * s + jnp.einsum(
            "bhd,bhe->bhde", k[:, t].astype(jnp.float32), v[:, t].astype(jnp.float32))
        ys.append(jnp.einsum("bhd,bhde->bhe", q[:, t].astype(jnp.float32), s))
    return jnp.stack(ys, axis=1).astype(v.dtype), s


def gla_decode_step(state, q, k, v, log_a):
    """One-token recurrent update. state [B,H,Dk,Dv]; q,k [B,H,Dk]; v [B,H,Dv]."""
    a = jnp.exp(log_a.astype(jnp.float32))
    s = a[..., None, None] * state.astype(jnp.float32) + jnp.einsum(
        "bhd,bhe->bhde", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), s)
    return s, y.astype(v.dtype)
