"""Mixture-of-Experts layer with capacity-based sorted dispatch.

Design (TPU-native, MaxText-style): tokens are routed top-k, sorted by
expert id, gathered into a dense [E, capacity, D] buffer, processed with
one batched einsum per projection (MXU-friendly), and scattered back with
gate weighting. Compiled FLOPs are O(E · capacity · D · F) ≈
O(tokens · top_k · cf · D · F) — the *active* compute, not n_experts×
dense compute, which keeps the roofline "useful FLOPs" ratio honest for
the 384-expert kimi-k2 config.

Expert parallelism: the leading E axis of the expert weights is sharded on
the "model" mesh axis when divisible (kimi: 384/16); otherwise the F axis
is sharded (mixtral: 8 experts < 16 shards ⇒ TP inside experts). GSPMD
turns the gather/scatter into all-to-all on the sharded axis.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_moe(key, cfg, *, dtype=jnp.float32):
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(D)
    p = {
        "router": L.dense_init(ks[0], D, E, dtype=jnp.float32),
        "w_gate": jax.random.normal(ks[1], (E, D, F), dtype) * s,
        "w_up": jax.random.normal(ks[2], (E, D, F), dtype) * s,
        "w_down": jax.random.normal(ks[3], (E, F, D), dtype) / math.sqrt(F),
    }
    if cfg.n_shared_experts:
        p["shared"] = L.init_mlp(ks[4], cfg, dtype=dtype,
                                 d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def _capacity(n_tokens: int, cfg) -> int:
    cap = int(math.ceil(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts))
    return max(8, ((cap + 7) // 8) * 8)  # pad to VPU sublane multiple


def moe_forward(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x [B,S,D] -> (y [B,S,D], aux_loss scalar). Dispatches to the
    shard_map expert-parallel path when cfg.moe_impl == "ep" and a mesh
    context is active (§Perf)."""
    if cfg.moe_impl == "ep":
        from repro.distributed.context import get_mesh
        mesh = get_mesh()
        if mesh is not None and "model" in mesh.axis_names \
                and cfg.n_experts % mesh.shape["model"] == 0:
            return moe_forward_ep(p, cfg, x, mesh)
    return _moe_forward_gather(p, cfg, x)


def _moe_forward_gather(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, D)

    logits = L.dense(p["router"], xt.astype(jnp.float32))      # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)             # [T,K]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # load-balance aux loss (Switch-style): E * mean(frac_tokens * frac_prob)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (T * K))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)

    # ---- sorted capacity dispatch ------------------------------------
    C = _capacity(T, cfg)
    flat_e = expert_idx.reshape(-1)                             # [T*K]
    flat_g = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(T), K)

    order = jnp.argsort(flat_e, stable=True)
    e_sorted = flat_e[order]
    # slot of each routed pair within its expert group
    pos = jnp.arange(T * K)
    seg_start = jnp.searchsorted(e_sorted, jnp.arange(E), side="left")
    slot = pos - seg_start[e_sorted]
    keep = slot < C
    dst = jnp.where(keep, e_sorted * C + slot, E * C)           # overflow bin

    buf = jnp.zeros((E * C + 1, D), x.dtype)
    buf = buf.at[dst].set(xt[flat_tok[order]])
    buf = buf[:-1].reshape(E, C, D)

    # ---- expert compute (batched, MXU) --------------------------------
    h_g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h_u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    h = jax.nn.silu(h_g) * h_u
    y_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])            # [E,C,D]

    # ---- weighted scatter back -----------------------------------------
    y_flat = y_e.reshape(E * C, D)
    gathered = jnp.where(keep[:, None], y_flat[jnp.minimum(dst, E * C - 1)], 0.0)
    contrib = gathered * flat_g[order][:, None].astype(x.dtype)
    y = jnp.zeros((T, D), x.dtype).at[flat_tok[order]].add(contrib)

    if "shared" in p:
        y = y + L.mlp(p["shared"], cfg, xt)
    return y.reshape(B, S, D), aux


def moe_forward_ep(p, cfg, x, mesh) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """§Perf: shard_map expert parallelism.

    Activations are batch-sharded over the data axes and replicated over
    "model"; expert banks are sharded on "model". Each model shard
    routes its (replicated) local tokens, keeps only assignments to ITS
    E/ms experts, runs them, and the partial outputs are combined with a
    single psum over "model" — the same collective shape as a
    row-parallel linear. This replaces the GSPMD-global argsort+scatter
    of the gather dispatch, whose all-to-all/all-gather volume made the
    MoE train shapes collective-bound (see EXPERIMENTS.md §Perf #1).
    """
    from jax.sharding import PartitionSpec as P
    # check_vma=False: with jax 0.8's varying-manual-axes checker enabled,
    # the TRANSPOSE of this body (sort+scatter over an input replicated on
    # "model", sharded on the data axes) produces silently wrong router
    # gradients on mixed meshes (verified against finite differences —
    # tests/test_moe_ep.py). With the checker off, gradients match the
    # dense oracle to 5e-7.
    try:
        from jax import shard_map
        _smap = lambda f, m, ins, outs: shard_map(
            f, mesh=m, in_specs=ins, out_specs=outs, check_vma=False)
    except ImportError:
        from jax.experimental.shard_map import shard_map as _sm
        _smap = lambda f, m, ins, outs: _sm(f, m, in_specs=ins, out_specs=outs,
                                            check_rep=False)

    B, Sq, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    dp = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
    ms = mesh.shape["model"]
    E_loc = E // ms
    import math as _m
    dsz = _m.prod(mesh.shape[a] for a in dp)
    T_loc = (B // dsz) * Sq if B % dsz == 0 else B * Sq
    # local capacity: expected local tokens routed to each local expert
    C = max(8, int(_m.ceil(T_loc * K * cfg.capacity_factor / E / 8)) * 8)

    def local_fn(x_loc, router_w, wg, wu, wd):
        # x_loc [b_loc, S, D] (replicated over model); wg [E_loc, D, F]
        b_loc = x_loc.shape[0]
        xt = x_loc.reshape(-1, D)
        T = xt.shape[0]
        logits = xt.astype(jnp.float32) @ router_w
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_idx = jax.lax.top_k(probs, K)
        gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
        # aux loss: identical on every model shard (x replicated there) —
        # pmean keeps the value but splits the cotangent 1/ms per shard so
        # the router gradient is not overcounted ms times
        me = jnp.mean(probs, axis=0)
        ce_ = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
            1.0 / (T * K))
        aux = cfg.router_aux_coef * E * jnp.sum(me * ce_)
        aux = jax.lax.pmean(aux, "model")

        my_lo = jax.lax.axis_index("model") * E_loc
        flat_e = expert_idx.reshape(-1)
        flat_g = gate_vals.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(T), K)
        local_e = jnp.where((flat_e >= my_lo) & (flat_e < my_lo + E_loc),
                            flat_e - my_lo, E_loc)          # E_loc = not mine
        order = jnp.argsort(local_e, stable=True)
        e_sorted = local_e[order]
        pos = jnp.arange(T * K)
        seg_start = jnp.searchsorted(e_sorted, jnp.arange(E_loc + 1),
                                     side="left")
        slot = pos - seg_start[jnp.minimum(e_sorted, E_loc)]
        keep = (e_sorted < E_loc) & (slot < C)
        dst = jnp.where(keep, e_sorted * C + slot, E_loc * C)
        buf = jnp.zeros((E_loc * C + 1, D), x_loc.dtype)
        buf = buf.at[dst].set(jnp.where(keep[:, None],
                                        xt[flat_tok[order]], 0))
        buf = buf[:-1].reshape(E_loc, C, D)
        h_g = jnp.einsum("ecd,edf->ecf", buf, wg)
        h_u = jnp.einsum("ecd,edf->ecf", buf, wu)
        y_e = jnp.einsum("ecf,efd->ecd", jax.nn.silu(h_g) * h_u, wd)
        y_flat = y_e.reshape(E_loc * C, D)
        gathered = jnp.where(keep[:, None],
                             y_flat[jnp.minimum(dst, E_loc * C - 1)], 0)
        contrib = gathered * flat_g[order][:, None].astype(x_loc.dtype)
        y = jnp.zeros((T, D), x_loc.dtype).at[flat_tok[order]].add(contrib)
        y = jax.lax.psum(y, "model")
        # scalar aux as a vector so it can ride the dp sharding
        aux_vec = jnp.full((b_loc,), aux / B, jnp.float32)
        return y.reshape(b_loc, Sq, D), aux_vec

    batch_spec = P(dp if B % dsz == 0 else None, None, None)
    y, aux_vec = _smap(
        local_fn, mesh,
        (batch_spec, P(None, None), P("model", None, None),
         P("model", None, None), P("model", None, None)),
        (batch_spec, P(dp if B % dsz == 0 else None)),
    )(x, p["router"]["w"], p["w_gate"], p["w_up"], p["w_down"])
    aux = jnp.sum(aux_vec)
    if "shared" in p:
        from repro.models import layers as L
        y = y + L.mlp(p["shared"], cfg, x)
    return y, aux


def moe_forward_dense(p, cfg, x) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Reference oracle: run every expert on every token, mask by gates."""
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    xt = x.reshape(-1, D)
    logits = L.dense(p["router"], xt.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    dense_gates = jnp.zeros_like(probs).at[
        jnp.arange(xt.shape[0])[:, None], expert_idx].set(gate_vals)  # [T,E]
    h = jax.nn.silu(jnp.einsum("td,edf->tef", xt, p["w_gate"])) * jnp.einsum(
        "td,edf->tef", xt, p["w_up"])
    y_all = jnp.einsum("tef,efd->ted", h, p["w_down"])
    y = jnp.einsum("ted,te->td", y_all, dense_gates.astype(x.dtype))
    if "shared" in p:
        y = y + L.mlp(p["shared"], cfg, xt)
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((E,), jnp.float32).at[expert_idx.reshape(-1)].add(
        1.0 / (xt.shape[0] * K))
    aux = cfg.router_aux_coef * E * jnp.sum(me * ce)
    return y.reshape(B, S, D), aux
