"""Per-family block stacks: init, full forward (train/prefill) and one-token
decode, all with ``lax.scan`` over stacked layer parameters (compile time
O(1) in depth; 88-layer mistral-large lowers as one scanned body).

Caches are pytrees whose leading axis is the layer stack, threaded through
the same scan.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models import kvcache as KV



# Cost-accounting hook: XLA's cost_analysis counts a while-loop body once,
# so the dry-run lowers shallow depth variants with fully-unrolled layer
# scans (set via set_scan_unroll) and extrapolates per-layer costs.
_SCAN_UNROLL = False


def set_scan_unroll(v: bool) -> None:
    global _SCAN_UNROLL
    _SCAN_UNROLL = bool(v)


def layer_scan(body, init, xs):
    return jax.lax.scan(body, init, xs, unroll=True if _SCAN_UNROLL else 1)

def stacked_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _maybe_remat(fn, enabled: bool, policy: Optional[str] = None):
    if not enabled:
        return fn
    pol = None
    if policy == "dots":
        pol = jax.checkpoint_policies.checkpoint_dots
    elif policy == "nothing":
        pol = jax.checkpoint_policies.nothing_saveable
    return jax.checkpoint(fn, policy=pol)


# ==========================================================================
# Dense / MoE / VLM decoder layer
# ==========================================================================

def init_decoder_layer(key, cfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    p = {
        "ln1": L.norm_init(cfg.d_model, dtype=dtype),
        "attn": L.init_attn(ks[0], cfg, dtype=dtype),
        "ln2": L.norm_init(cfg.d_model, dtype=dtype),
    }
    if cfg.family == "moe":
        p["moe"] = M.init_moe(ks[1], cfg, dtype=dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg, dtype=dtype)
    return p


def decoder_layer_fwd(p, cfg, h, *, window=None):
    """Full-sequence layer. Returns (h, aux)."""
    a = L.self_attention_block(p["attn"], cfg, L.rms_norm(p["ln1"], h, cfg.norm_eps),
                               causal=True, window=window)
    h = h + a
    hn = L.rms_norm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        y, aux = M.moe_forward(p["moe"], cfg, hn)
    else:
        y, aux = L.mlp(p["mlp"], cfg, hn), jnp.zeros((), jnp.float32)
    return h + y, aux


def decoder_layer_prefill(p, cfg, h, ck, cv, *, window=None):
    """Layer forward that also fills this layer's KV cache."""
    hn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], cfg, hn)
    out = L.full_attention(q, k, v, cfg, causal=True, window=window)
    b, s = h.shape[:2]
    h = h + L.dense(p["attn"]["wo"], out.reshape(b, s, cfg.q_dim))
    ck, cv = KV.write_prefill(ck, cv,
                              KV.expand_kv_for_cache(cfg, k).astype(ck.dtype),
                              KV.expand_kv_for_cache(cfg, v).astype(cv.dtype),
                              window)
    hn = L.rms_norm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        y, _ = M.moe_forward(p["moe"], cfg, hn)
    else:
        y = L.mlp(p["mlp"], cfg, hn)
    return h + y, ck, cv


def decoder_layer_decode(p, cfg, h, ck, cv, pos, *, window=None):
    """One-token layer step. h [B,1,D]; pos [B] absolute position."""
    hn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
    q, k, v = L.attn_qkv(p["attn"], cfg, hn, positions=pos[:, None])
    ck, cv = KV.write_decode(ck, cv,
                             KV.expand_kv_for_cache(cfg, k).astype(ck.dtype),
                             KV.expand_kv_for_cache(cfg, v).astype(cv.dtype),
                             pos, window)
    kvl = KV.valid_len(pos, ck.shape[1], window)
    # window=None here on purpose: rolling caches bound M to the window
    # and kv_len tracks validity. Under use_pallas() this is the batched
    # decode kernel — all slots/heads in one launch.
    out = L._dispatch_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                causal=False, window=None, kv_len=kvl)
    b = h.shape[0]
    h = h + L.dense(p["attn"]["wo"], out.reshape(b, 1, cfg.q_dim))
    hn = L.rms_norm(p["ln2"], h, cfg.norm_eps)
    if "moe" in p:
        y, _ = M.moe_forward(p["moe"], cfg, hn)
    else:
        y = L.mlp(p["mlp"], cfg, hn)
    return h + y, ck, cv


# ==========================================================================
# Decoder-only model (dense / moe / vlm)
# ==========================================================================

def init_decoder_model(key, cfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 4)
    Vp = cfg.padded_vocab()
    p = {
        "embed": L.init_embedding(ks[0], Vp, cfg.d_model, dtype=dtype),
        "layers": stacked_init(
            lambda k: init_decoder_layer(k, cfg, dtype=dtype), ks[1], cfg.n_layers),
        "final_norm": L.norm_init(cfg.d_model, dtype=dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.init_embedding(ks[2], Vp, cfg.d_model, dtype=dtype)
    return p


def _logits(p, cfg, h):
    head = p.get("lm_head", p["embed"])
    logits = L.unembed(head, h)
    if cfg.padded_vocab() != cfg.vocab_size:
        neg = jnp.asarray(-1e9, logits.dtype)
        pad = jnp.arange(cfg.padded_vocab()) >= cfg.vocab_size
        logits = jnp.where(pad, neg, logits)
    return logits


def _embed_inputs(p, cfg, batch):
    """Token embeddings, with VLM patch embeddings prepended (stub frontend)."""
    h = L.embed(p["embed"], batch["tokens"])
    if cfg.family == "vlm" and "patch_embeds" in batch:
        h = jnp.concatenate([batch["patch_embeds"].astype(h.dtype), h], axis=1)
    return h


def decoder_forward(p, cfg, batch, *, remat: bool = False,
                    remat_policy: Optional[str] = None):
    """Training/scoring forward. Returns (logits, aux_loss)."""
    h = _embed_inputs(p, cfg, batch)
    window = cfg.sliding_window

    def body(h, p_l):
        h, aux = decoder_layer_fwd(p_l, cfg, h, window=window)
        return h, aux

    h, auxs = layer_scan(_maybe_remat(body, remat, remat_policy), h, p["layers"])
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    n_img = cfg.n_image_patches if cfg.family == "vlm" else 0
    if n_img and h.shape[1] > n_img:
        h = h[:, n_img:]
    return _logits(p, cfg, h), jnp.sum(auxs)


def decoder_prefill(p, cfg, batch, cache):
    """Fill cache from a prompt; returns (last-token logits, cache)."""
    h = _embed_inputs(p, cfg, batch)
    window = cfg.decode_window()

    def body(h, xs):
        p_l, ck, cv = xs
        h, ck, cv = decoder_layer_prefill(p_l, cfg, h, ck, cv, window=window)
        return h, (ck, cv)

    h, (ck, cv) = layer_scan(body, h, (p["layers"], cache["k"], cache["v"]))
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h[:, -1:]), {"k": ck, "v": cv}


def _decoder_prefill_chunk_bucket(q, ck, cv, slot_idx, pos0, take, *,
                                  window=None, kv_width=None):
    """Gather each chunk row's bucketed cache window and attend it.

    Attention is bounded to ``kv_width`` cache lines (a static bucket
    >= max(pos0 + take)) instead of the full pool — chunk c costs
    O(S * kv_width) — and runs through the ragged dispatch: the Pallas
    ragged chunked-prefill kernel under ``use_pallas()``, the jnp twin
    (``layers.ragged_prefill_attention``) otherwise.
    """
    w = kv_width if kv_width is not None else ck.shape[1]
    ckg = jnp.take(ck[:, :w], slot_idx, axis=0)
    cvg = jnp.take(cv[:, :w], slot_idx, axis=0)
    return L._dispatch_attention(q, ckg.astype(q.dtype), cvg.astype(q.dtype),
                                 causal=True, window=window, q_offset=pos0,
                                 take=take)


def decoder_layer_prefill_chunk(p_l, cfg, h, ck, cv, slot_idx, positions,
                                pos0, take, *, window=None, kv_width=None):
    """Chunked-prefill layer step writing this layer's slot-pooled cache.

    h [G, S, D] chunk activations; ck/cv [B, M, KV, hd] — the FULL slot
    pool, not a per-request cache. Row ``g`` occupies pool row
    ``slot_idx[g]`` with its chunk starting at absolute offset
    ``pos0[g]``; only its first ``take[g]`` tokens are real (the rest is
    right-padding whose K/V lines are never attended: causal masking at
    per-row offsets keeps every valid query inside its own written span,
    and decode later masks by ``valid_len``). Attention runs against the
    row's full cache lines so later chunks see all earlier ones.
    """
    hn = L.rms_norm(p_l["ln1"], h, cfg.norm_eps)
    q, k, v = L.attn_qkv(p_l["attn"], cfg, hn, positions=positions)
    ck, cv = KV.write_chunk(ck, cv,
                            KV.expand_kv_for_cache(cfg, k).astype(ck.dtype),
                            KV.expand_kv_for_cache(cfg, v).astype(cv.dtype),
                            slot_idx, pos0, take)
    out = _decoder_prefill_chunk_bucket(q, ck, cv, slot_idx, pos0, take,
                                        window=window, kv_width=kv_width)
    g_, s_ = h.shape[:2]
    h = h + L.dense(p_l["attn"]["wo"], out.reshape(g_, s_, cfg.q_dim))
    hn = L.rms_norm(p_l["ln2"], h, cfg.norm_eps)
    # dense layers only (CHUNKED_PREFILL_FAMILIES): moe is excluded
    # because expert-capacity competition couples batch rows, which
    # would break the token-identity guarantee of this path
    y = L.mlp(p_l["mlp"], cfg, hn)
    return h + y, ck, cv


def decoder_prefill_chunk(p, cfg, tokens, cache, slot_idx, pos0, take,
                          kv_width=None):
    """Batched ragged chunked prefill for dense decoders.

    tokens [G, S] right-padded chunk ids; slot_idx [G] cache-pool rows;
    pos0 [G] absolute position of each row's tokens[:, 0]; take [G] valid
    token count per row (1 <= take <= S). KV lines land directly in the
    pooled ``cache`` (no per-request allocation/copy). ``kv_width`` — a
    static bound >= max(pos0 + take) — limits attention to that many
    cache lines instead of the whole pool. Returns (logits [G, 1, V] at
    each row's last valid token, cache) — the logits are only meaningful
    for rows whose prompt ends in this chunk.
    """
    h = L.embed(p["embed"], tokens)
    window = cfg.decode_window()
    S = tokens.shape[1]
    positions = pos0[:, None] + jnp.arange(S)[None, :]

    def body(h, xs):
        p_l, ck, cv = xs
        h, ck, cv = decoder_layer_prefill_chunk(
            p_l, cfg, h, ck, cv, slot_idx, positions, pos0, take,
            window=window, kv_width=kv_width)
        return h, (ck, cv)

    h, (ck, cv) = layer_scan(body, h, (p["layers"], cache["k"], cache["v"]))
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    last = jnp.take_along_axis(h, (take - 1)[:, None, None], axis=1)
    return _logits(p, cfg, last), {"k": ck, "v": cv}


def decoder_decode(p, cfg, token, pos, cache):
    """token [B,1]; pos [B]. Returns (logits [B,1,V], cache)."""
    h = L.embed(p["embed"], token)
    window = cfg.decode_window()

    if cfg.carry_cache:
        # §Perf: cache rides in the scan carry; the per-layer update is a
        # dynamic-update-slice that XLA performs in place inside the while
        # loop (the xs/ys form below double-buffers the ENTIRE cache every
        # decode step — ~2x cache bytes of avoidable HBM traffic).
        def body(carry, p_l):
            h, ck_all, cv_all, li = carry
            ck = jax.lax.dynamic_index_in_dim(ck_all, li, 0, keepdims=False)
            cv = jax.lax.dynamic_index_in_dim(cv_all, li, 0, keepdims=False)
            h, ck, cv = decoder_layer_decode(p_l, cfg, h, ck, cv, pos,
                                             window=window)
            ck_all = jax.lax.dynamic_update_index_in_dim(ck_all, ck, li, 0)
            cv_all = jax.lax.dynamic_update_index_in_dim(cv_all, cv, li, 0)
            return (h, ck_all, cv_all, li + 1), None

        (h, ck, cv, _), _ = layer_scan(
            body, (h, cache["k"], cache["v"], jnp.int32(0)), p["layers"])
    else:
        def body(h, xs):
            p_l, ck, cv = xs
            h, ck, cv = decoder_layer_decode(p_l, cfg, h, ck, cv, pos,
                                             window=window)
            return h, (ck, cv)

        h, (ck, cv) = layer_scan(body, h,
                                 (p["layers"], cache["k"], cache["v"]))
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h), {"k": ck, "v": cv}


# ==========================================================================
# Encoder-decoder (whisper)
# ==========================================================================

def init_enc_layer(key, cfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 2)
    return {
        "ln1": L.norm_init(cfg.d_model, bias=True, dtype=dtype),
        "attn": L.init_attn(ks[0], cfg, dtype=dtype),
        "ln2": L.norm_init(cfg.d_model, bias=True, dtype=dtype),
        "mlp": L.init_mlp(ks[1], cfg, dtype=dtype),
    }


def init_dec_layer(key, cfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    return {
        "ln1": L.norm_init(cfg.d_model, bias=True, dtype=dtype),
        "self_attn": L.init_attn(ks[0], cfg, dtype=dtype),
        "ln2": L.norm_init(cfg.d_model, bias=True, dtype=dtype),
        "cross_attn": L.init_attn(ks[1], cfg, dtype=dtype, cross=True),
        "ln3": L.norm_init(cfg.d_model, bias=True, dtype=dtype),
        "mlp": L.init_mlp(ks[2], cfg, dtype=dtype),
    }


def init_encdec_model(key, cfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    Vp = cfg.padded_vocab()
    return {
        "embed": L.init_embedding(ks[0], Vp, cfg.d_model, dtype=dtype),
        "enc_layers": stacked_init(
            lambda k: init_enc_layer(k, cfg, dtype=dtype), ks[1], cfg.n_encoder_layers),
        "enc_norm": L.norm_init(cfg.d_model, bias=True, dtype=dtype),
        "dec_layers": stacked_init(
            lambda k: init_dec_layer(k, cfg, dtype=dtype), ks[2], cfg.n_layers),
        "dec_norm": L.norm_init(cfg.d_model, bias=True, dtype=dtype),
        "lm_head": L.init_embedding(ks[3], Vp, cfg.d_model, dtype=dtype),
    }


def encode(p, cfg, frames):
    """frames [B, enc_seq, D] (stub conv frontend output) -> memory."""
    def body(h, p_l):
        hn = L.layer_norm(p_l["ln1"], h, cfg.norm_eps)
        h = h + L.self_attention_block(p_l["attn"], cfg, hn, causal=False)
        hn = L.layer_norm(p_l["ln2"], h, cfg.norm_eps)
        return h + L.mlp(p_l["mlp"], cfg, hn), None

    h, _ = layer_scan(body, frames, p["enc_layers"])
    return L.layer_norm(p["enc_norm"], h, cfg.norm_eps)


def _dec_layer(p_l, cfg, h, memory, *, self_fn):
    hn = L.layer_norm(p_l["ln1"], h, cfg.norm_eps)
    h, extra = self_fn(p_l["self_attn"], hn)
    hn = L.layer_norm(p_l["ln2"], h, cfg.norm_eps)
    h = h + L.cross_attention_block(p_l["cross_attn"], cfg, hn, memory)
    hn = L.layer_norm(p_l["ln3"], h, cfg.norm_eps)
    return h + L.mlp(p_l["mlp"], cfg, hn), extra


def encdec_forward(p, cfg, batch, **_):
    memory = encode(p, cfg, batch["frames"])
    h = L.embed(p["embed"], batch["tokens"])

    def body(h, p_l):
        def self_fn(pa, hn):
            return h + L.self_attention_block(pa, cfg, hn, causal=True), None
        h, _ = _dec_layer(p_l, cfg, h, memory, self_fn=self_fn)
        return h, None

    h, _ = layer_scan(body, h, p["dec_layers"])
    h = L.layer_norm(p["dec_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h), jnp.zeros((), jnp.float32)


def encdec_prefill(p, cfg, batch, cache):
    """Encode + prefill decoder self-attn cache + cache cross-attn k/v."""
    memory = encode(p, cfg, batch["frames"])
    h = L.embed(p["embed"], batch["tokens"])

    def body(h, xs):
        p_l, ck, cv = xs

        def self_fn(pa, hn):
            q, k, v = L.attn_qkv(pa, cfg, hn)
            out = L.attention(q, k, v, causal=True)
            b, s = hn.shape[:2]
            nck, ncv = KV.write_prefill(ck, cv, k.astype(ck.dtype),
                                        v.astype(cv.dtype), None)
            return h + L.dense(pa["wo"], out.reshape(b, s, cfg.q_dim)), (nck, ncv)

        h, (nck, ncv) = _dec_layer(p_l, cfg, h, memory, self_fn=self_fn)
        # cache this layer's cross k/v once
        xq, xk, xv = L.attn_qkv(p_l["cross_attn"], cfg, h[:, :1], kv_x=memory,
                                rope=False)
        return h, (nck, ncv, xk.astype(ck.dtype), xv.astype(cv.dtype))

    h, (ck, cv, xk, xv) = layer_scan(body, h, (p["dec_layers"], cache["k"], cache["v"]))
    h = L.layer_norm(p["dec_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h[:, -1:]), {"k": ck, "v": cv, "xk": xk, "xv": xv}


def encdec_decode(p, cfg, token, pos, cache):
    h = L.embed(p["embed"], token)

    def body(h, xs):
        p_l, ck, cv, xk, xv = xs
        hn = L.layer_norm(p_l["ln1"], h, cfg.norm_eps)
        q, k, v = L.attn_qkv(p_l["self_attn"], cfg, hn, positions=pos[:, None])
        nck, ncv = KV.write_decode(ck, cv, k.astype(ck.dtype), v.astype(cv.dtype),
                                   pos, None)
        kvl = KV.valid_len(pos, nck.shape[1], None)
        out = L._dispatch_attention(q, nck.astype(q.dtype),
                                    ncv.astype(q.dtype), causal=False,
                                    window=None, kv_len=kvl)
        b = h.shape[0]
        h = h + L.dense(p_l["self_attn"]["wo"], out.reshape(b, 1, cfg.q_dim))
        # cross-attn against cached encoder k/v
        hn = L.layer_norm(p_l["ln2"], h, cfg.norm_eps)
        xq = L.dense(p_l["cross_attn"]["wq"], hn).reshape(
            b, 1, cfg.n_heads, cfg.resolved_head_dim)
        out = L.attention(xq, xk.astype(xq.dtype), xv.astype(xq.dtype),
                          causal=False)
        h = h + L.dense(p_l["cross_attn"]["wo"], out.reshape(b, 1, cfg.q_dim))
        hn = L.layer_norm(p_l["ln3"], h, cfg.norm_eps)
        h = h + L.mlp(p_l["mlp"], cfg, hn)
        return h, (nck, ncv)

    h, (ck, cv) = layer_scan(body, h, (p["dec_layers"], cache["k"], cache["v"],
                                         cache["xk"], cache["xv"]))
    h = L.layer_norm(p["dec_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h), {"k": ck, "v": cv, "xk": cache["xk"], "xv": cache["xv"]}


# ==========================================================================
# Hybrid (zamba2): Mamba2 stack + ONE shared attention block every N layers
# ==========================================================================

def init_hybrid_model(key, cfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    Vp = cfg.padded_vocab()
    shared = {
        "ln1": L.norm_init(cfg.d_model, dtype=dtype),
        "attn": L.init_attn(ks[0], cfg, dtype=dtype),
        "ln2": L.norm_init(cfg.d_model, dtype=dtype),
        "mlp": L.init_mlp(ks[1], cfg, dtype=dtype),
    }
    return {
        "embed": L.init_embedding(ks[2], Vp, cfg.d_model, dtype=dtype),
        "mamba": stacked_init(
            lambda k: {"ln": L.norm_init(cfg.d_model, dtype=dtype),
                       "m": S.init_mamba2(k, cfg, dtype=dtype)},
            ks[3], cfg.n_layers),
        "shared_attn": shared,
        "final_norm": L.norm_init(cfg.d_model, dtype=dtype),
        "lm_head": L.init_embedding(ks[4], Vp, cfg.d_model, dtype=dtype),
    }


def _hybrid_segments(cfg):
    """Yield (start, stop) mamba segments; shared attn runs after each full one."""
    segs = []
    i = 0
    while i < cfg.n_layers:
        j = min(i + cfg.attn_every, cfg.n_layers)
        segs.append((i, j))
        i = j
    return segs


def _shared_attn_block(p, cfg, h, *, mode, cache=None, pos=None):
    hn = L.rms_norm(p["ln1"], h, cfg.norm_eps)
    window = cfg.decode_window()
    if mode == "full":
        h = h + L.self_attention_block(p["attn"], cfg, hn, causal=True,
                                       window=cfg.sliding_window)
        new_cache = None
    elif mode == "prefill":
        q, k, v = L.attn_qkv(p["attn"], cfg, hn)
        out = L.full_attention(q, k, v, cfg, causal=True, window=window)
        b, s = h.shape[:2]
        h = h + L.dense(p["attn"]["wo"], out.reshape(b, s, cfg.q_dim))
        ck, cv = KV.write_prefill(cache["k"], cache["v"], k.astype(cache["k"].dtype),
                                  v.astype(cache["v"].dtype), window)
        new_cache = {"k": ck, "v": cv}
    else:  # decode
        q, k, v = L.attn_qkv(p["attn"], cfg, hn, positions=pos[:, None])
        ck, cv = KV.write_decode(cache["k"], cache["v"], k.astype(cache["k"].dtype),
                                 v.astype(cache["v"].dtype), pos, window)
        kvl = KV.valid_len(pos, ck.shape[1], window)
        out = L._dispatch_attention(q, ck.astype(q.dtype), cv.astype(q.dtype),
                                    causal=False, window=None, kv_len=kvl)
        b = h.shape[0]
        h = h + L.dense(p["attn"]["wo"], out.reshape(b, 1, cfg.q_dim))
        new_cache = {"k": ck, "v": cv}
    hn = L.rms_norm(p["ln2"], h, cfg.norm_eps)
    return h + L.mlp(p["mlp"], cfg, hn), new_cache


def _tree_slice(tree, a, b):
    return jax.tree.map(lambda x: x[a:b], tree)


def hybrid_forward(p, cfg, batch, *, remat=False, remat_policy=None, **_):
    h = L.embed(p["embed"], batch["tokens"])

    def body(h, p_l):
        hn = L.rms_norm(p_l["ln"], h, cfg.norm_eps)
        y, _ = S.mamba2_forward(p_l["m"], cfg, hn)
        return h + y, None

    body = _maybe_remat(body, remat, remat_policy)
    for (a, b) in _hybrid_segments(cfg):
        h, _ = layer_scan(body, h, _tree_slice(p["mamba"], a, b))
        h, _ = _shared_attn_block(p["shared_attn"], cfg, h, mode="full")
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h), jnp.zeros((), jnp.float32)


def hybrid_init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16):
    n_seg = len(_hybrid_segments(cfg))
    mc = S.mamba2_init_cache(cfg, batch, dtype)
    return {
        "mamba": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape).copy(), mc),
        "attn": {
            "k": jnp.zeros((n_seg, batch, max_len, cfg.n_kv_heads,
                            cfg.resolved_head_dim), dtype),
            "v": jnp.zeros((n_seg, batch, max_len, cfg.n_kv_heads,
                            cfg.resolved_head_dim), dtype),
        },
    }


def _hybrid_stage(p, cfg, h, cache, *, mode, pos=None):
    def body(h, xs):
        p_l, c_l = xs
        hn = L.rms_norm(p_l["ln"], h, cfg.norm_eps)
        y, nc = S.mamba2_forward(p_l["m"], cfg, hn, initial=c_l)
        return h + y, nc

    new_mamba, new_attn = [], []
    for si, (a, b) in enumerate(_hybrid_segments(cfg)):
        h, nc = layer_scan(body, h, (_tree_slice(p["mamba"], a, b),
                                       _tree_slice(cache["mamba"], a, b)))
        new_mamba.append(nc)
        ac = jax.tree.map(lambda x: x[si], cache["attn"])
        h, nac = _shared_attn_block(p["shared_attn"], cfg, h, mode=mode,
                                    cache=ac, pos=pos)
        new_attn.append(nac)
    new_cache = {
        "mamba": jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *new_mamba),
        "attn": jax.tree.map(lambda *xs: jnp.stack(xs, axis=0), *new_attn),
    }
    return h, new_cache


def hybrid_prefill(p, cfg, batch, cache):
    h = L.embed(p["embed"], batch["tokens"])
    h, cache = _hybrid_stage(p, cfg, h, cache, mode="prefill")
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h[:, -1:]), cache


def hybrid_decode(p, cfg, token, pos, cache):
    h = L.embed(p["embed"], token)
    h, cache = _hybrid_stage(p, cfg, h, cache, mode="decode", pos=pos)
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h), cache


# ==========================================================================
# xLSTM (ssm family): groups of (mlstm_per_slstm mLSTM + 1 sLSTM)
# ==========================================================================

def _xlstm_groups(cfg) -> Tuple[int, int]:
    per = cfg.mlstm_per_slstm + 1
    n_groups = max(cfg.n_layers // per, 1)
    return n_groups, cfg.mlstm_per_slstm


def init_xlstm_model(key, cfg, *, dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    Vp = cfg.padded_vocab()
    n_groups, m_per = _xlstm_groups(cfg)

    def init_group(k):
        k1, k2 = jax.random.split(k)
        return {
            "mlstm": stacked_init(
                lambda kk: {"ln": L.norm_init(cfg.d_model, dtype=dtype),
                            "m": S.init_mlstm(kk, cfg, dtype=dtype)}, k1, m_per),
            "slstm": {"ln": L.norm_init(cfg.d_model, dtype=dtype),
                      "s": S.init_slstm(k2, cfg, dtype=dtype)},
        }

    return {
        "embed": L.init_embedding(ks[0], Vp, cfg.d_model, dtype=dtype),
        "groups": stacked_init(init_group, ks[1], n_groups),
        "final_norm": L.norm_init(cfg.d_model, dtype=dtype),
        "lm_head": L.init_embedding(ks[2], Vp, cfg.d_model, dtype=dtype),
    }


def _xlstm_group_apply(p_g, cfg, h, cache_g, *, decode: bool):
    _, m_per = _xlstm_groups(cfg)
    new_m = []
    for i in range(m_per):
        p_l = jax.tree.map(lambda x: x[i], p_g["mlstm"])
        hn = L.rms_norm(p_l["ln"], h, cfg.norm_eps)
        c = None if cache_g is None else jax.tree.map(lambda x: x[i], cache_g["mlstm"])
        fn = S.mlstm_decode if decode else S.mlstm_forward
        y, nc = fn(p_l["m"], cfg, hn, c) if decode else fn(p_l["m"], cfg, hn, initial=c)
        h = h + y
        new_m.append(nc)
    hn = L.rms_norm(p_g["slstm"]["ln"], h, cfg.norm_eps)
    c = None if cache_g is None else cache_g["slstm"]
    if decode:
        y, ns = S.slstm_decode(p_g["slstm"]["s"], cfg, hn, c)
    else:
        y, ns = S.slstm_forward(p_g["slstm"]["s"], cfg, hn, initial=c)
    h = h + y
    new_cache = {"mlstm": jax.tree.map(lambda *xs: jnp.stack(xs), *new_m),
                 "slstm": ns}
    return h, new_cache


def xlstm_apply(p, cfg, h, cache=None, *, decode=False):
    def body(h, xs):
        p_g, c_g = xs
        return _xlstm_group_apply(p_g, cfg, h, c_g, decode=decode)

    if cache is None:
        n_groups, _ = _xlstm_groups(cfg)
        # build a dummy cache pytree so scan has uniform xs
        c0 = xlstm_init_cache(cfg, h.shape[0], 0, h.dtype)
        h, new_cache = layer_scan(body, h, (p["groups"], c0))
    else:
        h, new_cache = layer_scan(body, h, (p["groups"], cache))
    return h, new_cache


def xlstm_init_cache(cfg, batch: int, max_len: int = 0, dtype=jnp.float32):
    n_groups, m_per = _xlstm_groups(cfg)
    mc = S.mlstm_init_cache(cfg, batch, dtype)
    sc = S.slstm_init_cache(cfg, batch, dtype)
    g = {
        "mlstm": jax.tree.map(
            lambda x: jnp.broadcast_to(x, (m_per,) + x.shape).copy(), mc),
        "slstm": sc,
    }
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_groups,) + x.shape).copy(), g)


def xlstm_forward(p, cfg, batch, **_):
    h = L.embed(p["embed"], batch["tokens"])
    h, _ = xlstm_apply(p, cfg, h)
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h), jnp.zeros((), jnp.float32)


def xlstm_prefill(p, cfg, batch, cache):
    h = L.embed(p["embed"], batch["tokens"])
    h, cache = xlstm_apply(p, cfg, h, cache)
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h[:, -1:]), cache


def xlstm_decode(p, cfg, token, pos, cache):
    h = L.embed(p["embed"], token)
    h, cache = xlstm_apply(p, cfg, h, cache, decode=True)
    h = L.rms_norm(p["final_norm"], h, cfg.norm_eps)
    return _logits(p, cfg, h), cache
