"""Recurrent blocks: Mamba2 (SSD) and xLSTM (mLSTM / sLSTM).

All sequence mixing is built on the chunked gated linear recurrence in
``linear_recurrence.py`` (TPU-native: intra-chunk MXU matmuls, inter-chunk
lax.scan), except sLSTM which is inherently sequential (lax.scan over T —
that is the architecture's trait, kept faithful).

Simplifications vs the source papers (recorded in DESIGN.md):
  * xLSTM exponential-gate stabilizer (m-state) replaced by sigmoid input
    gates — bounded, so no stabilizer is needed.
  * mLSTM's pre-qk causal conv4 is omitted.
  * Mamba2 uses a single B/C group shared across heads.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models.linear_recurrence import chunked_gla, gla_decode_step


# ==========================================================================
# Mamba2 (SSD)
# ==========================================================================

def init_mamba2(key, cfg, *, dtype=jnp.float32):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    ks = jax.random.split(key, 4)
    d_in = 2 * di + 2 * N + H  # z, x, B, C, dt
    return {
        "in_proj": L.dense_init(ks[0], cfg.d_model, d_in, dtype=dtype),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, di + 2 * N), dtype) * 0.1,
        "conv_b": jnp.zeros((di + 2 * N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),          # A = -exp(A_log) = -1
        "dt_bias": jnp.full((H,), math.log(math.e - 1), jnp.float32),  # softplus->1
        "D": jnp.ones((H,), jnp.float32),
        "norm": L.norm_init(di, dtype=dtype),
        "out_proj": L.dense_init(ks[2], di, cfg.d_model, dtype=dtype),
    }


def _causal_depthwise_conv(x, w, b, *, state=None):
    """x [B,T,D]; w [K,D]. Returns y [B,T,D] and new conv state [B,K-1,D]."""
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K))
    new_state = xp[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(y + b), new_state


def _mamba2_split(p, cfg, u):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads
    zxbcdt = L.dense(p["in_proj"], u)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * N]
    dt_pre = zxbcdt[..., di + di + 2 * N:]
    return z, xbc, dt_pre


def mamba2_forward(p, cfg, u, *, initial=None):
    """u [B,T,D] -> y [B,T,D], cache (conv_state, ssm_state)."""
    B, T, _ = u.shape
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    z, xbc, dt_pre = _mamba2_split(p, cfg, u)
    conv_state = None if initial is None else initial["conv"]
    xbc, conv_state = _causal_depthwise_conv(xbc, p["conv_w"], p["conv_b"],
                                             state=conv_state)
    x = xbc[..., :di].reshape(B, T, H, P)
    Bmat = xbc[..., di:di + N]                    # [B,T,N]
    Cmat = xbc[..., di + N:]                      # [B,T,N]
    dt = jax.nn.softplus(dt_pre.astype(jnp.float32) + p["dt_bias"])  # [B,T,H]
    A = -jnp.exp(p["A_log"])                      # [H]
    log_a = dt * A                                # [B,T,H]
    k = jnp.broadcast_to(Bmat[:, :, None, :], (B, T, H, N))
    q = jnp.broadcast_to(Cmat[:, :, None, :], (B, T, H, N))
    v = x * dt[..., None].astype(x.dtype)         # dt-scaled input
    ssm0 = None if initial is None else initial["ssm"]
    y, ssm_state = chunked_gla(q, k, v, log_a, initial_state=ssm0)
    y = y + p["D"][None, None, :, None].astype(y.dtype) * x
    y = y.reshape(B, T, di)
    y = L.rms_norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return L.dense(p["out_proj"], y), {"conv": conv_state, "ssm": ssm_state}


def mamba2_decode(p, cfg, u, cache):
    """u [B,1,D]; cache {conv:[B,K-1,dconv], ssm:[B,H,N,P]} -> y, new cache."""
    y, new_cache = mamba2_forward(p, cfg, u, initial=cache)
    return y, new_cache


def mamba2_init_cache(cfg, batch: int, dtype=jnp.float32):
    di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.n_ssm_heads, cfg.ssm_head_dim
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, di + 2 * N), dtype),
        "ssm": jnp.zeros((batch, H, N, P), jnp.float32),
    }


# ==========================================================================
# xLSTM — mLSTM block (matrix memory == gated linear attention)
# ==========================================================================

def init_mlstm(key, cfg, *, dtype=jnp.float32):
    di = cfg.d_inner
    H = cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "up": L.dense_init(ks[0], cfg.d_model, 2 * di, dtype=dtype),
        "wq": L.dense_init(ks[1], di, di, dtype=dtype),
        "wk": L.dense_init(ks[2], di, di, dtype=dtype),
        "wv": L.dense_init(ks[3], di, di, dtype=dtype),
        "w_gates": L.dense_init(ks[4], di, 2 * H, bias=True, dtype=dtype),
        "down": L.dense_init(ks[5], di, cfg.d_model, dtype=dtype),
        "norm": L.norm_init(di, dtype=dtype),
    }


def _mlstm_qkv(p, cfg, u):
    B, T, _ = u.shape
    di = cfg.d_inner
    H = cfg.n_heads
    P = di // H
    up = L.dense(p["up"], u)
    xi, zg = up[..., :di], up[..., di:]
    q = L.dense(p["wq"], xi).reshape(B, T, H, P) / math.sqrt(P)
    k = L.dense(p["wk"], xi).reshape(B, T, H, P)
    v = L.dense(p["wv"], xi).reshape(B, T, H, P)
    gates = L.dense(p["w_gates"], xi).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(gates[..., :H])           # [B,T,H]
    log_f = jax.nn.log_sigmoid(gates[..., H:])        # log forget gate
    k = k * i_gate[..., None].astype(k.dtype)
    # append normalizer channel: v' = [v, 1] so y' = [Cq, n·q]
    ones = jnp.ones(v.shape[:-1] + (1,), v.dtype)
    v1 = jnp.concatenate([v, ones], axis=-1)
    return q, k, v1, log_f, zg


def _mlstm_out(p, cfg, y1, zg, B, T):
    di = cfg.d_inner
    H = cfg.n_heads
    P = di // H
    y, n = y1[..., :P], y1[..., P:]
    h = y / jnp.maximum(jnp.abs(n), 1.0).astype(y.dtype)
    h = h.reshape(B, T, di)
    h = L.rms_norm(p["norm"], h, cfg.norm_eps) * jax.nn.silu(zg)
    return L.dense(p["down"], h)


def mlstm_forward(p, cfg, u, *, initial=None):
    B, T, _ = u.shape
    q, k, v1, log_f, zg = _mlstm_qkv(p, cfg, u)
    s0 = None if initial is None else initial["state"]
    y1, state = chunked_gla(q, k, v1, log_f, initial_state=s0)
    return _mlstm_out(p, cfg, y1, zg, B, T), {"state": state}


def mlstm_decode(p, cfg, u, cache):
    B, T, _ = u.shape
    q, k, v1, log_f, zg = _mlstm_qkv(p, cfg, u)
    state, y1 = gla_decode_step(cache["state"], q[:, 0], k[:, 0], v1[:, 0],
                                log_f[:, 0])
    return _mlstm_out(p, cfg, y1[:, None], zg, B, T), {"state": state}


def mlstm_init_cache(cfg, batch: int, dtype=jnp.float32):
    di = cfg.d_inner
    H = cfg.n_heads
    P = di // H
    return {"state": jnp.zeros((batch, H, P, P + 1), jnp.float32)}


# ==========================================================================
# xLSTM — sLSTM block (scalar memory, sequential)
# ==========================================================================

def init_slstm(key, cfg, *, dtype=jnp.float32):
    D = cfg.d_model
    H = cfg.n_heads
    P = D // H
    ks = jax.random.split(key, 3)
    return {
        "w_in": L.dense_init(ks[0], D, 4 * D, bias=True, dtype=dtype),
        # block-diagonal recurrent weights per head: [H, P, 4P]
        "r": jax.random.normal(ks[1], (H, P, 4 * P), dtype) / math.sqrt(P),
        "norm": L.norm_init(D, dtype=dtype),
        "out": L.dense_init(ks[2], D, D, dtype=dtype),
    }


def _slstm_cell(p, cfg, x_t, carry):
    """x_t [B,4D] (pre-activations from input); carry (c,n,h) each [B,H,P]."""
    D = cfg.d_model
    H = cfg.n_heads
    P = D // H
    c, n, h = carry
    rec = jnp.einsum("bhp,hpq->bhq", h, p["r"])          # [B,H,4P]
    pre = x_t.reshape(-1, H, 4 * P) + rec
    z, i, f, o = jnp.split(pre.astype(jnp.float32), 4, axis=-1)
    z = jnp.tanh(z)
    i = jax.nn.sigmoid(i)
    f = jax.nn.sigmoid(f)
    o = jax.nn.sigmoid(o)
    c = f * c + i * z
    n = f * n + i
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new.astype(h.dtype))


def slstm_forward(p, cfg, u, *, initial=None):
    B, T, D = u.shape
    H = cfg.n_heads
    P = D // H
    x_pre = L.dense(p["w_in"], u)                        # [B,T,4D]
    if initial is None:
        carry = (jnp.zeros((B, H, P), jnp.float32),
                 jnp.zeros((B, H, P), jnp.float32),
                 jnp.zeros((B, H, P), u.dtype))
    else:
        carry = (initial["c"], initial["n"], initial["h"])

    def step(carry, x_t):
        new = _slstm_cell(p, cfg, x_t, carry)
        return new, new[2]

    carry, hs = jax.lax.scan(step, carry, jnp.moveaxis(x_pre, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).reshape(B, T, D)
    h = L.rms_norm(p["norm"], h, cfg.norm_eps)
    y = L.dense(p["out"], h)
    cache = {"c": carry[0], "n": carry[1], "h": carry[2]}
    return y, cache


def slstm_decode(p, cfg, u, cache):
    return slstm_forward(p, cfg, u, initial=cache)


def slstm_init_cache(cfg, batch: int, dtype=jnp.float32):
    H = cfg.n_heads
    P = cfg.d_model // H
    return {
        "c": jnp.zeros((batch, H, P), jnp.float32),
        "n": jnp.zeros((batch, H, P), jnp.float32),
        "h": jnp.zeros((batch, H, P), dtype),
    }
