"""Core neural-net primitives (pure JAX, no flax).

Parameters are nested dicts of jnp arrays. Every init_* returns such a
dict; every apply function is pure. Attention dispatches to the Pallas
kernels in ``repro.kernels`` when ``repro.kernels.dispatch.use_pallas()``
is enabled; the default path is pure jnp (XLA) and is the oracle the
kernels are validated against.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, bias: bool = False, dtype=jnp.float32,
               scale: Optional[float] = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.normal(key, (d_in, d_out), dtype) * jnp.asarray(scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(d: int, *, bias: bool = False, dtype=jnp.float32):
    p = {"scale": jnp.ones((d,), dtype)}
    if bias:
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def rms_norm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


def layer_norm(p, x, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    if "bias" in p:
        y = y + p["bias"].astype(jnp.float32)
    return y.astype(dt)


# --------------------------------------------------------------------------
# rotary position embedding
# --------------------------------------------------------------------------

def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions [..., S] -> cos/sin [..., S, head_dim//2] (float32)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x [..., S, H, hd]; cos/sin [..., S, hd//2] (broadcast over heads)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., :, None, :]
    s = sin[..., :, None, :]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * c - x2f * s, x2f * c + x1f * s], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# attention (jnp reference path; Pallas kernels mirror this math)
# --------------------------------------------------------------------------

def _expand_kv(k, n_rep: int):
    """[B,S,KV,hd] -> [B,S,KV*n_rep,hd] by repeating each kv head."""
    if n_rep == 1:
        return k
    b, s, kv, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, n_rep, hd)
                            ).reshape(b, s, kv * n_rep, hd)


def attention(q, k, v, *, causal: bool, window: Optional[int] = None,
              q_offset=0, kv_len: Optional[jnp.ndarray] = None):
    """Scaled dot-product attention with GQA, causal and sliding-window masks.

    q: [B, Sq, H, hd]; k/v: [B, Sk, KV, hd]. ``q_offset`` is the absolute
    position of q[0] relative to k[0] (decode: Sk-1 typically) — a scalar,
    or a per-row [B] array (ragged chunked prefill: each batch row sits at
    its own offset into its KV lines).
    ``kv_len`` optionally masks out cache positions >= kv_len (ragged decode).
    Returns [B, Sq, H, hd].
    """
    b, sq, h, hd = q.shape
    sk, kv = k.shape[1], k.shape[2]
    n_rep = h // kv
    k = _expand_kv(k, n_rep)
    v = _expand_kv(v, n_rep)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_off = jnp.asarray(q_offset)
    if q_off.ndim:                                     # per-row offsets [B]
        qpos = jnp.arange(sq)[None, :, None] + q_off[:, None, None]  # [B,Sq,1]
        kpos = jnp.arange(sk)[None, None, :]                         # [1,1,Sk]
        mask = jnp.ones((b, sq, sk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if kv_len is not None:
            mask &= kpos < kv_len[:, None, None]
        mask = mask[:, None]                                      # [B,1,Sq,Sk]
    else:
        qpos = jnp.arange(sq)[:, None] + q_offset          # [Sq,1]
        kpos = jnp.arange(sk)[None, :]                     # [1,Sk]
        mask = jnp.ones((sq, sk), dtype=bool)
        if causal:
            mask &= kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        if kv_len is not None:
            mask = mask[None] & (kpos[None] < kv_len[:, None, None])  # [B,Sq,Sk]
            mask = mask[:, None]                                      # [B,1,Sq,Sk]
        else:
            mask = mask[None, None]
    scores = jnp.where(mask, scores, -jnp.inf)
    probs = jax.nn.softmax(scores, axis=-1)
    # rows that are fully masked produce NaN; zero them (cannot happen for
    # causal self-attention but can for ragged kv_len=0)
    probs = jnp.where(jnp.any(mask, axis=-1, keepdims=True), probs, 0.0)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v)
    return out


def ragged_prefill_attention(q, k, v, *, pos0, take=None,
                             window: Optional[int] = None):
    """Reference twin of ``kernels.ops.ragged_prefill_attention``.

    q [G,Sq,H,hd]; k/v [G,W,KV,hd]; pos0/take [G]. Row ``g`` carries
    ``take[g]`` valid query tokens whose absolute positions start at
    ``pos0[g]`` within its W cache lines; causal/window masks are applied
    at those per-row offsets, and padding query rows (>= take) are
    emitted as zeros exactly like the kernel (they never contaminate
    valid lanes: chunked prefill only writes/reads the first ``take``
    positions). ``take=None`` means every row is fully valid.
    """
    g, s = q.shape[:2]
    out = attention(q, k, v, causal=True, window=window, q_offset=pos0)
    if take is None:
        return out
    valid = jnp.arange(s)[None, :] < take[:, None]
    return jnp.where(valid[:, :, None, None], out, jnp.zeros_like(out))


def blocked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      q_offset=0, block_q: int = 512):
    """Flash-style attention at the XLA level: lax.map over q blocks with
    per-block fused mask+softmax. Never materializes the [B,H,Sq,Sk]
    score tensor — peak live bytes drop from O(Sq·Sk) to O(block_q·Sk).
    This is the §Perf fix for the memory-bound prefill shapes (the Pallas
    flash kernel is the TPU-native equivalent; this path is what the
    dry-run lowers).
    """
    b, sq, h, hd = q.shape
    pb = (-sq) % block_q
    if pb:
        q = jnp.pad(q, ((0, 0), (0, pb), (0, 0), (0, 0)))
    nblk = (sq + pb) // block_q

    def one_block(i):
        qi = jax.lax.dynamic_slice_in_dim(q, i * block_q, block_q, axis=1)
        return attention(qi, k, v, causal=causal, window=window,
                         q_offset=q_offset + i * block_q)

    out = jax.lax.map(one_block, jnp.arange(nblk))        # [nblk,B,bq,H,hd]
    out = jnp.moveaxis(out, 0, 1).reshape(b, sq + pb, h, hd)
    return out[:, :sq]


def init_attn(key, cfg, *, dtype=jnp.float32, cross: bool = False):
    ks = jax.random.split(key, 6)
    hd = cfg.resolved_head_dim
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.q_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wk": dense_init(ks[1], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wv": dense_init(ks[2], cfg.d_model, cfg.kv_dim, bias=cfg.qkv_bias, dtype=dtype),
        "wo": dense_init(ks[3], cfg.q_dim, cfg.d_model, dtype=dtype,
                         scale=1.0 / math.sqrt(cfg.q_dim)),
    }
    if cfg.qk_norm and not cross:
        p["q_norm"] = norm_init(hd, dtype=dtype)
        p["k_norm"] = norm_init(hd, dtype=dtype)
    return p


def attn_qkv(p, cfg, x, *, positions=None, kv_x=None, rope: bool = True):
    """Project to q/k/v heads; apply qk-norm and rope. kv_x for cross-attn."""
    b, s, _ = x.shape
    hd = cfg.resolved_head_dim
    kv_src = x if kv_x is None else kv_x
    skv = kv_src.shape[1]
    q = dense(p["wq"], x).reshape(b, s, cfg.n_heads, hd)
    k = dense(p["wk"], kv_src).reshape(b, skv, cfg.n_kv_heads, hd)
    v = dense(p["wv"], kv_src).reshape(b, skv, cfg.n_kv_heads, hd)
    if "q_norm" in p:
        q = rms_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_norm(p["k_norm"], k, cfg.norm_eps)
    if rope:
        qpos = positions if positions is not None else jnp.arange(s)
        cos, sin = rope_cos_sin(qpos, hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        if kv_x is None:
            k = apply_rope(k, cos, sin)
        else:
            kcos, ksin = rope_cos_sin(jnp.arange(skv), hd, cfg.rope_theta)
            k = apply_rope(k, kcos, ksin)
    return q, k, v


def _seq_shard(x, cfg):
    if not cfg.shard_attn_seq:
        return x
    from jax.sharding import PartitionSpec as P
    try:
        return jax.lax.with_sharding_constraint(
            x, P(None, "model", *([None] * (x.ndim - 2))))
    except (ValueError, RuntimeError):   # no mesh context (CPU tests)
        return x


def full_attention(q, k, v, cfg, *, causal, window):
    """Training/prefill attention honoring the §Perf knobs."""
    bq = cfg.attention_block_q
    if cfg.shard_attn_seq:
        q = _seq_shard(q, cfg)
    if bq is not None and q.shape[1] > bq:
        out = blocked_attention(q, k, v, causal=causal, window=window,
                                block_q=bq)
    else:
        out = _dispatch_attention(q, k, v, causal=causal, window=window)
    if cfg.shard_attn_seq:
        out = _seq_shard(out, cfg)
    return out


def self_attention_block(p, cfg, x, *, causal=True, window=None):
    """Full-sequence self-attention (training / prefill)."""
    q, k, v = attn_qkv(p, cfg, x)
    out = full_attention(q, k, v, cfg, causal=causal, window=window)
    b, s, _, _ = q.shape
    return dense(p["wo"], out.reshape(b, s, cfg.q_dim))


def cross_attention_block(p, cfg, x, memory):
    q, k, v = attn_qkv(p, cfg, x, kv_x=memory, rope=False)
    out = _dispatch_attention(q, k, v, causal=False, window=None)
    b, s, _, _ = q.shape
    return dense(p["wo"], out.reshape(b, s, cfg.q_dim))


def _dispatch_attention(q, k, v, *, causal, window, q_offset=0, kv_len=None,
                        take=None):
    from repro.kernels import dispatch as kd
    q_off = jnp.asarray(q_offset)
    if q_off.ndim and causal and kv_len is None:
        # per-row offsets [B]: ragged chunked prefill
        if kd.use_pallas():
            from repro.kernels import ops as kops
            tk = (take if take is not None
                  else jnp.full((q.shape[0],), q.shape[1], jnp.int32))
            return kops.ragged_prefill_attention(q, k, v, q_off, tk,
                                                 window=window)
        return ragged_prefill_attention(q, k, v, pos0=q_off, take=take,
                                        window=window)
    if kd.use_pallas() and kv_len is None and q.shape[1] > 1:
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    q_offset=q_offset)
    if kd.use_pallas() and q.shape[1] == 1 and kv_len is not None:
        from repro.kernels import ops as kops
        return kops.decode_attention(q, k, v, kv_len=kv_len, window=window)
    return attention(q, k, v, causal=causal, window=window,
                     q_offset=q_offset, kv_len=kv_len)


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

def init_mlp(key, cfg, *, dtype=jnp.float32, d_ff: Optional[int] = None):
    d_ff = d_ff if d_ff is not None else cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.activation == "swiglu":
        return {
            "w_gate": dense_init(ks[0], cfg.d_model, d_ff, dtype=dtype),
            "w_up": dense_init(ks[1], cfg.d_model, d_ff, dtype=dtype),
            "w_down": dense_init(ks[2], d_ff, cfg.d_model, dtype=dtype),
        }
    return {
        "w_up": dense_init(ks[0], cfg.d_model, d_ff, bias=True, dtype=dtype),
        "w_down": dense_init(ks[1], d_ff, cfg.d_model, bias=True, dtype=dtype),
    }


def mlp(p, cfg, x):
    if "w_gate" in p:
        return dense(p["w_down"], jax.nn.silu(dense(p["w_gate"], x)) * dense(p["w_up"], x))
    return dense(p["w_down"], jax.nn.gelu(dense(p["w_up"], x)))


# --------------------------------------------------------------------------
# embedding / unembedding
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d_model: int, *, dtype=jnp.float32):
    return {"w": jax.random.normal(key, (vocab, d_model), dtype) * 0.02}


def embed(p, tokens):
    return p["w"][tokens]


def unembed(p, x):
    return x @ p["w"].T


def softmax_cross_entropy(logits, labels, *, ignore_id: int = -1):
    """Mean CE over non-ignored positions. logits [..., V], labels [...].

    The gold logit is selected with a fused one-hot reduction rather than
    take_along_axis: a gather over a vocab-sharded logits tensor forces
    GSPMD to all-gather the full logits (hundreds of GB at train_4k);
    the iota-compare-multiply-reduce form stays sharded and fuses.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    V = logits.shape[-1]
    onehot = (labels[..., None] == jnp.arange(V, dtype=labels.dtype))
    gold = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    nll = logz - gold
    ok = labels != ignore_id
    return jnp.sum(nll * ok) / jnp.maximum(jnp.sum(ok), 1)
