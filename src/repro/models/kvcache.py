"""KV / recurrent-state caches for serving.

Attention caches are either *full* ([B, max_len, KV, hd] per layer, write
at absolute position) or *rolling* (size = window W, write at pos % W) —
the rolling buffer is what makes long_500k decode O(window) for SWA archs
(Mistral-style). Keys are stored post-RoPE, so buffer order is irrelevant
(softmax is permutation-invariant over keys); validity is tracked by a
per-request ``pos`` counter: valid slots = min(pos, W).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def cache_len(cfg, shape_seq: int, *, margin: int = 8) -> int:
    w = cfg.decode_window()
    if w is not None:
        return w
    return shape_seq + margin


def stored_kv_heads(cfg) -> int:
    """KV heads as stored in the cache. §Perf: expanding GQA heads to the
    model-axis size aligns each chip's cache shard with its q-head group,
    eliminating per-layer cache re-gather at decode (2x memory for the
    8->16 mistral-large case, minus tens of GB of collectives)."""
    return cfg.kv_cache_expand_heads or cfg.n_kv_heads


def expand_kv_for_cache(cfg, k):
    """[B,S,KV,hd] -> [B,S,stored,hd] by repeating each kv head."""
    tgt = stored_kv_heads(cfg)
    kv = k.shape[2]
    if tgt == kv:
        return k
    rep = tgt // kv
    b, s, _, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, hd)
                            ).reshape(b, s, tgt, hd)


def init_attn_cache(cfg, batch: int, max_len: int, n_layers: int,
                    dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    kvh = stored_kv_heads(cfg)
    return {
        "k": jnp.zeros((n_layers, batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, kvh, hd), dtype),
    }


def write_prefill(cache_k, cache_v, k, v, window: Optional[int]):
    """Single-layer prefill write. cache [B,M,KV,hd]; k/v [B,S,KV,hd].

    Rolling buffers store position p at slot p % M; when the prompt is
    longer than the buffer we keep the last M tokens and roll them into
    their canonical slots so later decode writes evict the oldest entry.
    """
    M = cache_k.shape[1]
    S = k.shape[1]
    if window is not None and S > M:
        k, v = k[:, -M:], v[:, -M:]
        shift = (S - M) % M
        if shift:
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
        S = M
    cache_k = cache_k.at[:, :S].set(k)
    cache_v = cache_v.at[:, :S].set(v)
    return cache_k, cache_v


def write_chunk(cache_k, cache_v, k, v, slot_idx, pos0, take):
    """Batched ragged chunk write into a slot-pooled cache.

    cache [B, M, KV, hd] (the engine's shared pool); k/v [G, S, KV, hd]
    right-padded prompt chunks. One batched scatter per cache tensor: row
    ``g`` writes its first ``take[g]`` lines at absolute positions
    [pos0[g], pos0[g]+take[g]) of pool row ``slot_idx[g]`` — no
    per-request cache allocation and no full-pool copy on the host; XLA
    updates a donated pool in place. Padded positions are routed out of
    bounds and dropped, so they can never corrupt lines a row already
    owns and the compiled program is one scatter regardless of G.
    """
    M = cache_k.shape[1]
    G, S = k.shape[:2]
    assert S <= M, f"chunk width {S} exceeds cache lines {M}"
    cols = pos0[:, None] + jnp.arange(S)[None, :]            # [G, S]
    cols = jnp.where(jnp.arange(S)[None, :] < take[:, None], cols, M)
    rows = slot_idx[:, None]                                 # [G, 1]
    cache_k = cache_k.at[rows, cols].set(k, mode="drop")
    cache_v = cache_v.at[rows, cols].set(v, mode="drop")
    return cache_k, cache_v


def write_decode(cache_k, cache_v, k, v, pos, window: Optional[int]):
    """Write one token at per-request absolute position ``pos`` [B]."""
    import jax.numpy as jnp
    M = cache_k.shape[1]
    b = jnp.arange(cache_k.shape[0])
    slot = pos % M if window is not None else jnp.minimum(pos, M - 1)
    cache_k = cache_k.at[b, slot].set(k[:, 0])
    cache_v = cache_v.at[b, slot].set(v[:, 0])
    return cache_k, cache_v


def valid_len(pos, max_len: int, window: Optional[int]):
    """Number of valid cache slots after writing token at ``pos`` [B]."""
    import jax.numpy as jnp
    n = pos + 1
    return jnp.minimum(n, max_len)
