"""KV / recurrent-state caches for serving.

Attention caches are either *full* ([B, max_len, KV, hd] per layer, write
at absolute position) or *rolling* (size = window W, write at pos % W) —
the rolling buffer is what makes long_500k decode O(window) for SWA archs
(Mistral-style). Keys are stored post-RoPE, so buffer order is irrelevant
(softmax is permutation-invariant over keys); validity is tracked by a
per-request ``pos`` counter: valid slots = min(pos, W).
"""
from __future__ import annotations

import zlib
from typing import List, Optional

import jax.numpy as jnp
import numpy as np

# Token-block granularity for cross-request KV prefix reuse: prefixes are
# hashed (and shared) in units of this many tokens, so a borrower can only
# skip prefill for whole blocks it matches exactly.
PREFIX_BLOCK = 16


def cache_len(cfg, shape_seq: int, *, margin: int = 8) -> int:
    w = cfg.decode_window()
    if w is not None:
        return w
    return shape_seq + margin


def stored_kv_heads(cfg) -> int:
    """KV heads as stored in the cache. §Perf: expanding GQA heads to the
    model-axis size aligns each chip's cache shard with its q-head group,
    eliminating per-layer cache re-gather at decode (2x memory for the
    8->16 mistral-large case, minus tens of GB of collectives)."""
    return cfg.kv_cache_expand_heads or cfg.n_kv_heads


def expand_kv_for_cache(cfg, k):
    """[B,S,KV,hd] -> [B,S,stored,hd] by repeating each kv head."""
    tgt = stored_kv_heads(cfg)
    kv = k.shape[2]
    if tgt == kv:
        return k
    rep = tgt // kv
    b, s, _, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kv, rep, hd)
                            ).reshape(b, s, tgt, hd)


def init_attn_cache(cfg, batch: int, max_len: int, n_layers: int,
                    dtype=jnp.bfloat16):
    hd = cfg.resolved_head_dim
    kvh = stored_kv_heads(cfg)
    return {
        "k": jnp.zeros((n_layers, batch, max_len, kvh, hd), dtype),
        "v": jnp.zeros((n_layers, batch, max_len, kvh, hd), dtype),
    }


def write_prefill(cache_k, cache_v, k, v, window: Optional[int]):
    """Single-layer prefill write. cache [B,M,KV,hd]; k/v [B,S,KV,hd].

    Rolling buffers store position p at slot p % M; when the prompt is
    longer than the buffer we keep the last M tokens and roll them into
    their canonical slots so later decode writes evict the oldest entry.
    """
    M = cache_k.shape[1]
    S = k.shape[1]
    if window is not None and S > M:
        k, v = k[:, -M:], v[:, -M:]
        shift = (S - M) % M
        if shift:
            k = jnp.roll(k, shift, axis=1)
            v = jnp.roll(v, shift, axis=1)
        S = M
    cache_k = cache_k.at[:, :S].set(k)
    cache_v = cache_v.at[:, :S].set(v)
    return cache_k, cache_v


def write_chunk(cache_k, cache_v, k, v, slot_idx, pos0, take):
    """Batched ragged chunk write into a slot-pooled cache.

    cache [B, M, KV, hd] (the engine's shared pool); k/v [G, S, KV, hd]
    right-padded prompt chunks. One batched scatter per cache tensor: row
    ``g`` writes its first ``take[g]`` lines at absolute positions
    [pos0[g], pos0[g]+take[g]) of pool row ``slot_idx[g]`` — no
    per-request cache allocation and no full-pool copy on the host; XLA
    updates a donated pool in place. Padded positions are routed out of
    bounds and dropped, so they can never corrupt lines a row already
    owns and the compiled program is one scatter regardless of G.
    """
    M = cache_k.shape[1]
    G, S = k.shape[:2]
    assert S <= M, f"chunk width {S} exceeds cache lines {M}"
    cols = pos0[:, None] + jnp.arange(S)[None, :]            # [G, S]
    cols = jnp.where(jnp.arange(S)[None, :] < take[:, None], cols, M)
    rows = slot_idx[:, None]                                 # [G, 1]
    cache_k = cache_k.at[rows, cols].set(k, mode="drop")
    cache_v = cache_v.at[rows, cols].set(v, mode="drop")
    return cache_k, cache_v


def prefix_block_hashes(ids, block: int = PREFIX_BLOCK) -> List[int]:
    """Chained crc32 per full token block: ``hashes[b]`` covers tokens
    [0, (b+1)*block), so two prompts sharing hash ``b`` share (modulo
    collisions, which the index resolves by exact token comparison) their
    whole first ``(b+1)*block`` tokens — a single int per boundary gives
    longest-prefix lookup without storing every sub-prefix."""
    n = (len(ids) // block) * block
    if n == 0:
        return []
    arr = np.asarray(ids[:n], np.int32)
    out, h = [], 0
    for i in range(0, n, block):
        h = zlib.crc32(arr[i:i + block].tobytes(), h)
        out.append(h)
    return out


def copy_prefix(cache_k, cache_v, src_idx, dst_idx, length, width: int):
    """Batched cross-slot prefix copy on a stacked [L, B, M, KV, hd] pool.

    Row ``g`` copies cache lines [0, length[g]) of pool slot ``src_idx[g]``
    into slot ``dst_idx[g]`` across every layer at once — one gather plus
    one drop-mode scatter per cache tensor (the batched dynamic-update
    idiom of :func:`write_chunk`), regardless of how many borrowers seed
    this step. ``width`` is the static gather width (>= max(length));
    lines beyond ``length[g]`` are routed out of bounds and dropped.
    """
    M = cache_k.shape[2]
    G = src_idx.shape[0]
    assert width <= M, f"copy width {width} exceeds cache lines {M}"
    src_k = cache_k[:, src_idx, :width]                      # [L,G,W,KV,hd]
    src_v = cache_v[:, src_idx, :width]
    cols = jnp.broadcast_to(jnp.arange(width)[None, :], (G, width))
    cols = jnp.where(cols < length[:, None], cols, M)        # [G, W]
    rows = dst_idx[:, None]                                  # [G, 1]
    cache_k = cache_k.at[:, rows, cols].set(src_k, mode="drop")
    cache_v = cache_v.at[:, rows, cols].set(src_v, mode="drop")
    return cache_k, cache_v


def write_decode(cache_k, cache_v, k, v, pos, window: Optional[int]):
    """Write one token at per-request absolute position ``pos`` [B]."""
    import jax.numpy as jnp
    M = cache_k.shape[1]
    b = jnp.arange(cache_k.shape[0])
    slot = pos % M if window is not None else jnp.minimum(pos, M - 1)
    cache_k = cache_k.at[b, slot].set(k[:, 0])
    cache_v = cache_v.at[b, slot].set(v[:, 0])
    return cache_k, cache_v


def valid_len(pos, max_len: int, window: Optional[int]):
    """Number of valid cache slots after writing token at ``pos`` [B]."""
    import jax.numpy as jnp
    n = pos + 1
    return jnp.minimum(n, max_len)
