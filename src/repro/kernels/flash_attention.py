"""Flash attention (prefill) Pallas TPU kernel.

Online-softmax attention with explicit VMEM tiling: grid
(B, H, Sq/BQ, Sk/BK); the innermost KV dimension is sequential
("arbitrary") and carries (m, l, acc) scratch accumulators in VMEM.
Supports causal masking, sliding windows, GQA (q-head -> kv-head via the
BlockSpec index map), and a q_offset for chunked/continuation prefill.
Block shapes default to 128x128 — MXU-aligned (128x128 systolic array),
and the [BQ, hd] x [hd, BK] matmuls hit the MXU with no relayout.

Validated against ref.attention_ref in interpret mode on CPU
(tests/test_kernels.py sweeps shapes/dtypes); on TPU drop interpret.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: Optional[int],
                  q_offset: int, sq: int, sk: int, bq: int, bk: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)            # [bk, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0) + q_offset
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = kpos < sk                                # padded kv columns
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_bhsd(q, k, v, *, causal: bool = True,
                         window: Optional[int] = None, q_offset: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = True):
    """q [B,H,Sq,hd]; k/v [B,KV,Sk,hd] -> o [B,H,Sq,hd]."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    g = H // KV
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(Sk, 8))
    pq = (-Sq) % bq
    pk = (-Sk) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // bq
    nk = (Sk + pk) // bk
    grid = (B, H, nq, nk)

    kernel = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(hd), causal=causal,
        window=window, q_offset=q_offset, sq=Sq, sk=Sk, bq=bq, bk=bk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, i, j, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :Sq]
