"""Batched decode-attention Pallas TPU kernel: every serving slot in ONE
launch.

The serving engine's fused decode step advances all G KV slots by one
token per tick. Its attention is: one query token per slot over the
slot-pooled [B, KV, M, hd] cache, masked to each slot's valid length.
The original ``decode_attention_bhmd`` kernel already streamed KV blocks
but gridded (B, H, M/BK) — B*H tiny per-head steps per tick. This
sibling covers the whole batch's head stack in a (B, M/BK) grid:

* every block carries ALL H query heads, GQA-folded onto their KV head
  ([B,H,hd] -> [B, KV, grp, hd], padded up to an 8-row sublane tile) so
  the score and weighted-value contractions are each one KV-batched
  ``dot_general`` per block — one MXU issue for the whole slot's heads;
* per-slot ``kv_len`` rides in scalar-prefetch SMEM; the mask is
  ``kpos < kv_len[b]`` and, with a sliding ``window`` over a full
  (non-rolling) cache, ``kpos >= kv_len[b] - window``;
* KV blocks entirely past ``kv_len[b]`` (or below the window) are
  skipped via ``pl.when`` — a slot early in its generation pays
  O(kv_len), not O(M). ``kv_len == 0`` rows skip every block and emit
  exact zeros (the safe-denominator finish);
* the innermost KV walk is sequential ("arbitrary"): Mosaic's automatic
  pipeline double-buffers the next KV block's DMA against the current
  block's compute, with the q block resident across the walk.

Rolling-window caches already bound M to the window and track validity
via ``kv_len``, so the engine passes ``window=None``; the explicit
``window`` mask is for full caches (parity-tested in
``tests/test_batched_decode_kernel.py``).

Sampling is NOT part of this kernel — it fuses at the XLA level: the
engine's jitted decode step (``serving.engine._jit_steps``) runs
model-with-this-kernel + ``_device_sample`` in one compiled program, so
there is no separate host-visible sample op per token.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30
_SUBLANE = 8   # pad the folded [KV, grp, hd] q tile up to 8 sublane rows


# Ref order contract (checked statically by reprolint pallas-contract):
# 1 scalar-prefetch ref (kv_len), then in_specs, out, scratch — the
# signature arity must match the PrefetchScalarGridSpec below.
def _batched_decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                           m_scr, l_scr, acc_scr, *, scale: float,
                           window: Optional[int], bk: int, gp: int):
    b = pl.program_id(0)
    ki = pl.program_id(1)
    nk = pl.num_programs(1)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    kv_len = kv_len_ref[b]
    # dead-block skip: nothing valid at or past kv_len; with a window,
    # nothing below kv_len - window either. kv_len == 0 skips everything.
    needed = ki * bk < kv_len
    if window is not None:
        needed &= ki * bk + bk > kv_len - window

    @pl.when(needed)
    def _block():
        q = q_ref[0].astype(jnp.float32)                # [KV, gp, hd]
        k = k_ref[0].astype(jnp.float32)                # [KV, bk, hd]
        v = v_ref[0].astype(jnp.float32)
        kv = k.shape[0]

        s = jax.lax.dot_general(q, k, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32) * scale
        # s [KV, gp, bk]; the mask is head-independent
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (gp, bk), 1)
        mask = kpos < kv_len
        if window is not None:
            mask &= kpos >= kv_len - window
        maskf = jnp.broadcast_to(mask[None], (kv, gp, bk))
        s = jnp.where(maskf, s, NEG_INF)

        m_prev = m_scr[...]                             # [KV, gp]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.where(maskf, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + jax.lax.dot_general(
            p, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=2)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)   # kv_len==0 rows -> zeros
        o_ref[0] = (acc_scr[...] / safe[..., None]).astype(o_ref.dtype)


def batched_decode_attention_bhmd(q, k, v, kv_len, *,
                                  window: Optional[int] = None,
                                  bk: int = 256, interpret: bool = True):
    """q [B,H,hd]; k/v [B,KV,M,hd]; kv_len [B] -> o [B,H,hd].

    ``bk`` is clamped to the cache width (non-multiple tails are padded
    and masked), so small-cache configs neither fail nor over-read.
    """
    B, H, hd = q.shape
    KV, M = k.shape[1], k.shape[2]
    grp = H // KV
    gp = max(grp, _SUBLANE)
    bk = min(bk, max(M, 8))
    pk = (-M) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nk = (M + pk) // bk
    # GQA-fold query heads onto their KV head, pad the group rows to a
    # sublane tile (padding rows are zero: they cost nothing and their
    # outputs are sliced away — zeros stay finite through the softmax)
    qf = q.reshape(B, KV, grp, hd)
    if gp != grp:
        qf = jnp.pad(qf, ((0, 0), (0, 0), (0, gp - grp), (0, 0)))

    kernel = functools.partial(_batched_decode_kernel,
                               scale=1.0 / math.sqrt(hd), window=window,
                               bk=bk, gp=gp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, nk),
        in_specs=[
            pl.BlockSpec((1, KV, gp, hd), lambda b, j, kv_len: (b, 0, 0, 0)),
            pl.BlockSpec((1, KV, bk, hd), lambda b, j, kv_len: (b, 0, j, 0)),
            pl.BlockSpec((1, KV, bk, hd), lambda b, j, kv_len: (b, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, KV, gp, hd),
                               lambda b, j, kv_len: (b, 0, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, gp), jnp.float32),
            pltpu.VMEM((KV, gp), jnp.float32),
            pltpu.VMEM((KV, gp, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, gp, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32), qf, k, v)
    return out[:, :, :grp].reshape(B, H, hd)
