"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

from typing import Optional



def attention_ref(q, k, v, *, causal: bool = True,
                  window: Optional[int] = None, q_offset: int = 0):
    """q [B,Sq,H,hd]; k/v [B,Sk,KV,hd] -> [B,Sq,H,hd] (f32 softmax)."""
    from repro.models.layers import attention
    return attention(q, k, v, causal=causal, window=window, q_offset=q_offset)


def ragged_prefill_attention_ref(q, k, v, pos0, take, *,
                                 window: Optional[int] = None):
    """q [G,S,H,hd]; k/v [G,W,KV,hd]; pos0/take [G] -> [G,S,H,hd]."""
    from repro.models.layers import ragged_prefill_attention
    return ragged_prefill_attention(q, k, v, pos0=pos0, take=take,
                                    window=window)


def decode_attention_ref(q, k, v, kv_len, *, window: Optional[int] = None):
    """q [B,1,H,hd]; k/v [B,M,KV,hd]; kv_len [B] -> [B,1,H,hd].

    With ``window`` the query sits at absolute position ``kv_len - 1`` of
    a full (non-rolling) cache, so valid keys are
    ``kv_len - window <= kpos < kv_len`` — the per-row ``q_offset`` form
    of ``layers.attention`` expresses exactly that mask.
    """
    from repro.models.layers import attention
    if window is None:
        return attention(q, k, v, causal=False, kv_len=kv_len)
    return attention(q, k, v, causal=False, window=window,
                     q_offset=kv_len - 1, kv_len=kv_len)


def rmsnorm_ref(x, scale, *, eps: float = 1e-5):
    from repro.models.layers import rms_norm
    return rms_norm({"scale": scale}, x, eps)
