"""Per-head decode-attention Pallas TPU kernel (flash-decode over a long
KV cache).

One query token per request attends over a [B, M, KV, hd] cache with a
per-request valid length. Grid (B, H, M/BK): KV blocks stream through
VMEM sequentially with online-softmax scratch, so the VMEM working set is
O(BK·hd) regardless of context length.

This is the original per-head kernel, kept as the simple reference shape
for roofline comparisons; the serving engine dispatches the batched
sibling (``batched_decode_attention``) which covers the whole GQA head
stack of every slot in a (B, M/BK) grid — B*H fewer grid steps per
decode tick.

The q row (1 x hd) is padded to an 8-row sublane tile; masking keeps the
math exact. kv_len rides in SMEM via PrefetchScalarGridSpec. ``bk`` is
clamped to the cache width M (and non-multiple tails are padded and
masked), so a small-cache config neither fails to tile nor over-reads —
the default bk=512 is a cap, not a requirement.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30
_QROWS = 8  # sublane padding for the single query row


def _decode_kernel(kv_len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_scr, l_scr, acc_scr, *, scale: float, bk: int):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)             # [_QROWS, hd]
    k = k_ref[0, 0].astype(jnp.float32)             # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (_QROWS, bk), 1)
    mask = kpos < kv_len_ref[b]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def decode_attention_bhmd(q, k, v, kv_len, *, bk: int = 512,
                          interpret: bool = True):
    """q [B,H,hd]; k/v [B,KV,M,hd]; kv_len [B] -> o [B,H,hd]."""
    B, H, hd = q.shape
    KV, M = k.shape[1], k.shape[2]
    g = H // KV
    bk = min(bk, max(M, 8))
    pk = (-M) % bk
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nk = (M + pk) // bk
    qp = jnp.broadcast_to(q[:, :, None, :], (B, H, _QROWS, hd))

    kernel = functools.partial(_decode_kernel, scale=1.0 / math.sqrt(hd), bk=bk)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, H, nk),
        in_specs=[
            pl.BlockSpec((1, 1, _QROWS, hd), lambda b, h, j, kv_len: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, kv_len, g=g: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, j, kv_len, g=g: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, _QROWS, hd),
                               lambda b, h, j, kv_len: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((_QROWS,), jnp.float32),
            pltpu.VMEM((_QROWS,), jnp.float32),
            pltpu.VMEM((_QROWS, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, _QROWS, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(kv_len, jnp.int32), qp, k, v)
    return out[:, :, 0]
