"""Jit'd public wrappers around the Pallas kernels.

Model code calls these through layers._dispatch_attention with the
[B,S,H,hd] layout; the wrappers transpose to the kernels' [B,H,S,hd]
blocked layout, handle GQA head mapping and padding, and pick interpret
mode automatically (CPU containers interpret; real TPUs compile).
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.kernels.flash_attention import flash_attention_bhsd
from repro.kernels.batched_decode_attention import batched_decode_attention_bhmd
from repro.kernels.ragged_prefill_attention import ragged_prefill_attention_bhsd
from repro.kernels.rmsnorm import rmsnorm_2d


@partial(jax.jit, static_argnames=("causal", "window", "q_offset", "bq", "bk"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_offset: int = 0,
                    bq: int = 128, bk: int = 128):
    """q [B,Sq,H,hd]; k/v [B,Sk,KV,hd] -> [B,Sq,H,hd]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                             q_offset=q_offset, bq=bq, bk=bk,
                             interpret=dispatch.interpret_mode())
    return jnp.swapaxes(o, 1, 2)


@partial(jax.jit, static_argnames=("window", "bq", "bk"))
def ragged_prefill_attention(q, k, v, pos0, take, *,
                             window: Optional[int] = None,
                             bq: int = 128, bk: int = 256):
    """q [G,S,H,hd]; k/v [G,W,KV,hd]; pos0/take [G] -> [G,S,H,hd].

    Batched ragged chunked-prefill attention: row ``g`` holds ``take[g]``
    valid query tokens at absolute offset ``pos0[g]`` into its W pooled
    KV lines (W is the engine's static ``kv_width`` bucket). Padding
    query rows (>= take) come back as zeros. Defaults are the tuned
    serving blocks (bq = one engine chunk, bk = half a max-width cache
    walk — see the kernel module docstring for the rationale).
    """
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = ragged_prefill_attention_bhsd(qt, kt, vt, pos0, take, window=window,
                                      bq=bq, bk=bk,
                                      interpret=dispatch.interpret_mode())
    return jnp.swapaxes(o, 1, 2)


@partial(jax.jit, static_argnames=("window", "bk"))
def decode_attention(q, k, v, *, kv_len, window: Optional[int] = None,
                     bk: int = 256):
    """q [B,1,H,hd]; k/v [B,M,KV,hd]; kv_len [B] -> [B,1,H,hd].

    One launch covers every slot: the batched decode kernel grids
    (B, M/bk) with the whole GQA head stack folded into each block and
    per-slot ``kv_len`` in SMEM. Rolling-window caches already bound M
    to the window and the engine passes ``window=None``; an explicit
    ``window`` applies the sliding mask over a full cache
    (``kv_len - window <= kpos < kv_len``).
    """
    qt = q[:, 0]                                     # [B,H,hd]
    kt = jnp.swapaxes(k, 1, 2)                       # [B,KV,M,hd]
    vt = jnp.swapaxes(v, 1, 2)
    o = batched_decode_attention_bhmd(qt, kt, vt, kv_len, window=window,
                                      bk=bk,
                                      interpret=dispatch.interpret_mode())
    return o[:, None]


@partial(jax.jit, static_argnames=("chunk",))
def chunked_gla(q, k, v, log_a, *, chunk: int = 128):
    """q,k [B,T,H,Dk]; v [B,T,H,Dv]; log_a [B,T,H] -> y [B,T,H,Dv].

    Pallas kernel for the Mamba2/mLSTM recurrence (models use the XLA path
    in models/linear_recurrence.py; this is the TPU-native equivalent).
    """
    from repro.kernels.chunked_gla import chunked_gla_bhtd
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    lat = jnp.moveaxis(log_a, 1, 2)
    y = chunked_gla_bhtd(qt, kt, vt, lat, chunk=chunk,
                         interpret=dispatch.interpret_mode())
    return jnp.moveaxis(y, 1, 2)


@partial(jax.jit, static_argnames=("eps", "bn"))
def rmsnorm(x, scale, *, eps: float = 1e-5, bn: int = 256):
    """x [..., D] -> [..., D]."""
    shape = x.shape
    y = rmsnorm_2d(x.reshape(-1, shape[-1]), scale, eps=eps, bn=bn,
                   interpret=dispatch.interpret_mode())
    return y.reshape(shape)
