"""Chunked gated linear recurrence Pallas TPU kernel.

The Mamba2 (SSD) / mLSTM compute hot spot: for per-(batch, head) scalar
decays a_t,

    S_t = a_t · S_{t-1} + k_t v_tᵀ ;  y_t = q_t · S_t

Grid (B, H, T/C): the chunk dimension is sequential ("arbitrary") and
carries the [Dk, Dv] state in VMEM scratch; each step does the intra-chunk
quadratic form (tri-masked decay attention — two MXU matmuls) plus the
inter-chunk state contribution, then advances the state. Mirrors
models/linear_recurrence.chunked_gla (the XLA path the models use) and is
validated against gla_reference in interpret mode.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params


# Ref order contract (checked statically by reprolint pallas-contract):
# no scalar prefetch — 4 in_specs, 1 out, 1 VMEM scratch, in order.
def _gla_kernel(q_ref, k_ref, v_ref, la_ref, o_ref, s_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    q = q_ref[0, 0].astype(jnp.float32)          # [C, Dk]
    k = k_ref[0, 0].astype(jnp.float32)          # [C, Dk]
    v = v_ref[0, 0].astype(jnp.float32)          # [C, Dv]
    la = la_ref[0, 0].astype(jnp.float32)        # [C] (padded lanes are 0)

    cum = jnp.cumsum(la)                          # within-chunk log decay
    total = cum[-1]

    # intra-chunk: y[i] = sum_{j<=i} exp(cum_i - cum_j) (q_i·k_j) v_j
    qk = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_j = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    tri = iota_i >= iota_j
    decay = jnp.where(tri, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    y = jax.lax.dot(qk * decay, v, preferred_element_type=jnp.float32)

    # inter-chunk: y[i] += exp(cum_i) · q_i · S_prev
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot(
        q, s_scr[...], preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)

    # state update: S = exp(total)·S_prev + Σ_j exp(total - cum_j) k_j v_jᵀ
    kdec = k * jnp.exp(total - cum)[:, None]
    s_scr[...] = jnp.exp(total) * s_scr[...] + jax.lax.dot_general(
        kdec, v, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)


def chunked_gla_bhtd(q, k, v, log_a, *, chunk: int = 128,
                     interpret: bool = True):
    """q,k [B,H,T,Dk]; v [B,H,T,Dv]; log_a [B,H,T] -> y [B,H,T,Dv].

    T is padded to a chunk multiple with log_a=0, k=v=0 (identity steps).
    """
    B, H, T, Dk = q.shape
    Dv = v.shape[-1]
    C = min(chunk, max(T, 8))
    pt = (-T) % C
    if pt:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pt), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pt), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pt), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, 0), (0, pt)))
    nc = (T + pt) // C

    out = pl.pallas_call(
        functools.partial(_gla_kernel, chunk=C),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, C, Dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, Dk), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C, Dv), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, C), lambda b, h, c: (b, h, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, C, Dv), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, T + pt, Dv), v.dtype),
        scratch_shapes=[pltpu.VMEM((Dk, Dv), jnp.float32)],
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, log_a)
    return out[:, :, :T]
