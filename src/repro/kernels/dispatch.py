"""Global switch between the jnp reference path and Pallas TPU kernels.

On TPU, enable with ``set_use_pallas(True)`` (or REPRO_USE_PALLAS=1). On
CPU the kernels run in interpret mode and are only used by the kernel
tests/benchmarks; models default to the XLA path.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

_USE_PALLAS = os.environ.get("REPRO_USE_PALLAS", "0") == "1"
_INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "")


def use_pallas() -> bool:
    return _USE_PALLAS


def set_use_pallas(v: bool) -> None:
    global _USE_PALLAS
    _USE_PALLAS = bool(v)


def interpret_mode() -> bool:
    """Interpret unless explicitly disabled (real TPU)."""
    if _INTERPRET:
        return _INTERPRET == "1"
    import jax
    return jax.default_backend() != "tpu"


def tpu_compiler_params(**kwargs):
    """Version-portable TPU compiler params: the class was renamed from
    ``TPUCompilerParams`` to ``CompilerParams`` across JAX releases."""
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams
    return cls(**kwargs)


@contextmanager
def pallas_enabled(v: bool = True):
    old = use_pallas()
    set_use_pallas(v)
    try:
        yield
    finally:
        set_use_pallas(old)
