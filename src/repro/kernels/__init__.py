"""Pallas TPU kernels for the serving/training hot paths.

Five kernels, each with a pure-jnp oracle (``ref.py``) it is allclose-
validated against in interpret mode on CPU:

* ``flash_attention``          — online-softmax prefill/training attention
  (causal, sliding window, GQA, scalar ``q_offset``), grid (B,H,Sq/BQ,Sk/BK).
* ``ragged_prefill_attention`` — batched ragged chunked-prefill attention
  for the slot-pooled serving cache: per-row ``pos0``/``take`` in scalar-
  prefetch SMEM, KV bounded to the engine's ``kv_width`` bucket, fully
  masked blocks skipped.
* ``batched_decode_attention`` — one launch per decode tick: every slot's
  whole GQA head stack in a (B, M/BK) grid with per-slot ``kv_len`` in
  SMEM; this is what the engine dispatches.
* ``decode_attention``         — the original per-head flash-decode kernel,
  kept as the simple reference shape for roofline comparisons.
* ``chunked_gla``              — chunked gated-linear-attention scan for the
  Mamba2/mLSTM recurrence.

(plus ``rmsnorm``, a small VPU warm-up kernel.)

Block-size / grid tuning. The CI container runs the kernels in interpret
mode, where every grid step lowers to its own chain of XLA ops — so the
dominant cost is the *number of grid steps*, not arithmetic. The two
serving kernels are therefore shaped to minimise launches:

* All H query heads are folded into each block and the GQA groups are
  reshaped ``[H, bq, hd] -> [KV, grp*bq, hd]`` so the score and
  weighted-value contractions are single KV-batched ``dot_general`` calls
  per block instead of a per-head loop — this removed the H multiplier
  from the grid (the prefill grid is (G, Sq/BQ, W/BK); decode is
  (B, M/BK)).
* Defaults ``bq=128`` / ``bk=256`` make one engine prefill chunk a single
  q block and halve the KV walk relative to square 128-blocks; on the
  serving microbench shapes this is the difference between the Pallas
  path losing ~3x to the fused-einsum reference and beating it
  (see ``benchmarks/serve_throughput.py`` prefill/decode microbenches,
  gated by ``benchmarks/check_bench.py``).
* The KV walk is the innermost "arbitrary" grid dimension while the q
  block's index map stays fixed across it, so Mosaic's pipeliner keeps q
  resident in VMEM and double-buffers the next KV block's copy against
  the current block's compute (on real TPUs; interpret mode simply skips
  revisited copies).
* Fully-masked blocks are skipped with ``pl.when`` (a real ``lax.cond``
  at runtime, not just a mask) — verified to actually fire on serving
  traces by NaN-poisoning dead KV in
  ``tests/test_ragged_prefill_kernel.py::test_masked_block_skip_fires``.

Dispatch contract: model code never imports kernels directly — it calls
``layers._dispatch_attention`` / ``layers.ragged_prefill_attention``,
which route to the jit'd wrappers in ``ops.py`` when
``dispatch.use_pallas()`` is on (REPRO_USE_PALLAS=1 or
``pallas_enabled(True)``) and to the jnp reference otherwise. The
wrappers own layout transposes ([B,S,H,hd] model layout -> [B,H,S,hd]
blocked layout), GQA head mapping, padding to block multiples, and
interpret-mode selection (CPU interprets; real TPUs compile). Decode
sampling is fused at the XLA level: ``engine._jit_steps`` jits the
attention output straight into ``_device_sample`` so the sampled token
ids are the only per-tick host transfer.
"""
