"""Pallas TPU kernels for the serving/training hot paths.

Four kernels, each with a pure-jnp oracle (``ref.py``) it is allclose-
validated against in interpret mode on CPU:

* ``flash_attention``          — online-softmax prefill/training attention
  (causal, sliding window, GQA, scalar ``q_offset``), grid (B,H,Sq/BQ,Sk/BK).
* ``ragged_prefill_attention`` — batched ragged chunked-prefill attention
  for the slot-pooled serving cache: per-row ``pos0``/``take`` in scalar-
  prefetch SMEM, KV bounded to the engine's ``kv_width`` bucket, fully
  masked blocks skipped.
* ``decode_attention``         — flash-decode: one query token per request
  over a [B,M,KV,hd] cache with per-request ``kv_len``.
* ``chunked_gla``              — chunked gated-linear-attention scan for the
  Mamba2/mLSTM recurrence.

(plus ``rmsnorm``, a small VPU warm-up kernel.)

Dispatch contract: model code never imports kernels directly — it calls
``layers._dispatch_attention`` / ``layers.ragged_prefill_attention``,
which route to the jit'd wrappers in ``ops.py`` when
``dispatch.use_pallas()`` is on (REPRO_USE_PALLAS=1 or
``pallas_enabled(True)``) and to the jnp reference otherwise. The
wrappers own layout transposes ([B,S,H,hd] model layout -> [B,H,S,hd]
blocked layout), GQA head mapping, padding to block multiples, and
interpret-mode selection (CPU interprets; real TPUs compile).
"""
