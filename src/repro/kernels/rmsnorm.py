"""RMSNorm Pallas TPU kernel.

Row-tiled: grid (N/BN,); each program normalizes a [BN, D] block in VMEM
(f32 accumulation, cast back to the input dtype). Memory-bound by design
— the point of the kernel is a single HBM round-trip with fused scale.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


def rmsnorm_2d(x, scale, *, eps: float = 1e-5, bn: int = 256,
               interpret: bool = True):
    """x [N, D], scale [D] -> [N, D]."""
    N, D = x.shape
    bn = min(bn, max(N, 8))
    pn = (-N) % bn
    if pn:
        x = jnp.pad(x, ((0, pn), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((N + pn) // bn,),
        in_specs=[
            pl.BlockSpec((bn, D), lambda i: (i, 0)),
            pl.BlockSpec((D,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, D), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N + pn, D), x.dtype),
        interpret=interpret,
    )(x, scale)
    return out[:N]
