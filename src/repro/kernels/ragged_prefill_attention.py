"""Ragged chunked-prefill flash-attention Pallas TPU kernel.

The serving engine's batched chunked prefill (``serve_prefill_chunk``)
runs G co-resident prompt chunks through one padded call: row ``g`` holds
``take[g]`` valid query tokens whose absolute positions start at
``pos0[g]``, attending that row's slot-pooled KV lines bounded to a
static ``kv_width`` bucket. This kernel is the TPU-native version of that
attention: grid (G, H, Sq/BQ, W/BK), innermost KV dimension sequential
("arbitrary") with online-softmax (m, l, acc) scratch in VMEM.

Per-row raggedness rides in scalar-prefetch SMEM (``pos0``, ``take``
int32 [G]); masking is computed against ``pos0[g] + row``:

* query rows >= ``take[g]`` are padding — fully masked, emitted as zeros
  (``take[g] == 0`` rows — pure padding — are all zeros);
* causal: key position <= query position, which also fences off stale
  pool lines past ``pos0[g] + take[g]`` (later chunks see every line an
  earlier chunk wrote, and nothing a previous slot tenant left behind);
* optional sliding ``window``: key position > query position - window.

KV blocks that no valid query row of the current q-block can attend
(beyond the causal extent, or entirely below the window) are skipped via
``pl.when`` — a row early in its prompt pays O(pos0 + take), not
O(kv_width). GQA maps q-head h to kv-head h // (H // KV) in the
BlockSpec index maps, like the dense flash kernel.

Validated against layers.ragged_prefill_attention (the jnp reference
twin) in interpret mode on CPU (tests/test_ragged_prefill_kernel.py);
on TPU drop interpret.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


def _ragged_prefill_kernel(pos0_ref, take_ref, q_ref, k_ref, v_ref, o_ref,
                           m_scr, l_scr, acc_scr, *, scale: float,
                           window: Optional[int], bq: int, bk: int):
    g = pl.program_id(0)
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos0 = pos0_ref[g]
    take = take_ref[g]

    # Block-level early exit: the largest valid query row in this q-block
    # is min(take, (qi+1)*bq) - 1, so its causal extent ends at
    # pos0 + that row; a KV block starting past it is fully masked. With a
    # sliding window, blocks entirely below qpos_min - window are dead too.
    row_hi = jnp.minimum(take, (qi + 1) * bq) - 1          # -1 when take==0
    needed = (qi * bq < take) & (ki * bk <= pos0 + row_hi)
    if window is not None:
        needed &= ki * bk + bk > pos0 + qi * bq - window

    @pl.when(needed)
    def _block():
        q = q_ref[0, 0].astype(jnp.float32)                # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)                # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)

        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale

        row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        qpos = pos0 + row
        mask = (row < take) & (kpos <= qpos)
        if window is not None:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot(
            p, v, preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)   # fully-masked rows -> zeros
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


def ragged_prefill_attention_bhsd(q, k, v, pos0, take, *,
                                  window: Optional[int] = None,
                                  bq: int = 128, bk: int = 128,
                                  interpret: bool = True):
    """q [G,H,Sq,hd]; k/v [G,KV,W,hd]; pos0/take [G] -> o [G,H,Sq,hd]."""
    G, H, Sq, hd = q.shape
    KV, W = k.shape[1], k.shape[2]
    grp = H // KV
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(W, 8))
    pq = (-Sq) % bq
    pk = (-W) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // bq
    nk = (W + pk) // bk

    kernel = functools.partial(
        _ragged_prefill_kernel, scale=1.0 / math.sqrt(hd), window=window,
        bq=bq, bk=bk)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd),
                         lambda g, h, i, j, pos0, take: (g, h, i, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda g, h, i, j, pos0, take, grp=grp:
                         (g, h // grp, j, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda g, h, i, j, pos0, take, grp=grp:
                         (g, h // grp, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda g, h, i, j, pos0, take: (g, h, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, H, Sq + pq, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(pos0, jnp.int32), jnp.asarray(take, jnp.int32), q, k, v)
    return out[:, :, :Sq]
