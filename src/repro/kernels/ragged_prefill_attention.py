"""Ragged chunked-prefill flash-attention Pallas TPU kernel.

The serving engine's batched chunked prefill (``serve_prefill_chunk``)
runs G co-resident prompt chunks through one padded call: row ``g`` holds
``take[g]`` valid query tokens whose absolute positions start at
``pos0[g]``, attending that row's slot-pooled KV lines bounded to a
static ``kv_width`` bucket. This kernel is the TPU-native version of that
attention.

Launch geometry (tuned — see the block-size rationale below):

* grid ``(G, Sq/BQ, W/BK)`` with the KV dimension innermost and
  sequential ("arbitrary"), online-softmax (m, l, acc) scratch in VMEM;
* every block carries ALL H query heads and ALL KV heads — one grid
  step computes the whole head stack. GQA query heads are folded into
  their KV group ([H, bq, hd] -> [KV, grp*bq, hd]) so the score and
  weighted-value contractions are each a single KV-batched
  ``dot_general`` instead of H per-head matmuls. Compared to the
  original (G, H, Sq/BQ, W/BK) per-head grid this cuts the step count
  by H (fewer, larger MXU issues; far less per-step grid overhead —
  the term that dominated in interpret mode and lost the CPU microbench
  3x), and it reads each KV block once per q-block instead of once per
  q-head.
* **KV double-buffering**: the innermost KV walk is what Mosaic's
  automatic pipeline double-buffers — while the current KV block is in
  the MXU, the next block's DMA is in flight. The index maps are
  arranged so the q block is revisited (constant across the KV walk,
  fetched once) and only k/v stream, which is exactly the layout the
  pipeliner wants: q-block compute overlaps the next KV-block load.
* **Block-size rationale**: ``bq=128`` matches the MXU tile and keeps
  one q block per serving chunk (engine chunks are <= 128 tokens);
  ``bk=256`` halves the number of sequential KV steps vs 128 (fewer
  pipeline stalls and fewer grid steps) while a [KV, 256, hd] block
  still fits VMEM comfortably for every serving config in the zoo
  (worst case KV=8, hd=128: 1 MiB/buffer). Shrink ``bk`` before ``bq``
  if a future config overflows VMEM: the q block is reused W/bk times,
  the KV block only grp times.

Per-row raggedness rides in scalar-prefetch SMEM (``pos0``, ``take``
int32 [G]); masking is computed against ``pos0[g] + row``:

* query rows >= ``take[g]`` are padding — fully masked, emitted as zeros
  (``take[g] == 0`` rows — pure padding — are all zeros);
* causal: key position <= query position, which also fences off stale
  pool lines past ``pos0[g] + take[g]`` (later chunks see every line an
  earlier chunk wrote, and nothing a previous slot tenant left behind);
* optional sliding ``window``: key position > query position - window.

KV blocks that no valid query row of the current q-block can attend
(beyond the causal extent, or entirely below the window) are skipped via
``pl.when`` — a row early in its prompt pays O(pos0 + take), not
O(kv_width); q-blocks past ``take`` skip the whole KV walk. The skip is
verified to actually fire on engine-shaped traces by
``tests/test_ragged_prefill_kernel.py::test_masked_block_skip_fires``
(NaN-poisoned dead blocks must never contaminate the output — a masked
block that is *computed* rather than skipped turns into NaN through the
0 * NaN weighted-value product).

Validated against layers.ragged_prefill_attention (the jnp reference
twin) in interpret mode on CPU (tests/test_ragged_prefill_kernel.py);
on TPU drop interpret.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.dispatch import tpu_compiler_params

NEG_INF = -1e30


# Ref order contract (checked statically by reprolint pallas-contract):
# 2 scalar-prefetch refs (pos0, take), then in_specs, out, scratch —
# the signature arity must match the PrefetchScalarGridSpec below, and
# every BlockSpec index map stays pure arithmetic over
# (grid indices..., prefetch refs...).
def _ragged_prefill_kernel(pos0_ref, take_ref, q_ref, k_ref, v_ref, o_ref,
                           m_scr, l_scr, acc_scr, *, scale: float,
                           window: Optional[int], bq: int, bk: int,
                           grp: int):
    g = pl.program_id(0)
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos0 = pos0_ref[g]
    take = take_ref[g]

    # Block-level early exit: the largest valid query row in this q-block
    # is min(take, (qi+1)*bq) - 1, so its causal extent ends at
    # pos0 + that row; a KV block starting past it is fully masked. With a
    # sliding window, blocks entirely below qpos_min - window are dead too.
    # Fully-padded q-blocks (qi*bq >= take) skip the whole KV walk.
    row_hi = jnp.minimum(take, (qi + 1) * bq) - 1          # -1 when take==0
    needed = (qi * bq < take) & (ki * bk <= pos0 + row_hi)
    if window is not None:
        needed &= ki * bk + bk > pos0 + qi * bq - window

    @pl.when(needed)
    def _block():
        q = q_ref[0].astype(jnp.float32)                   # [H, bq, hd]
        k = k_ref[0].astype(jnp.float32)                   # [KV, bk, hd]
        v = v_ref[0].astype(jnp.float32)
        kv = k.shape[0]
        hd = q.shape[2]
        # fold each GQA group's q heads onto their shared KV head: head
        # h = kvh*grp + j lands at rows [j*bq, (j+1)*bq) of batch row kvh
        qg = q.reshape(kv, grp * bq, hd)

        s = jax.lax.dot_general(qg, k, (((2,), (2,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32) * scale
        # s [KV, grp*bq, bk]
        row = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        qpos = pos0 + row
        mask = (row < take) & (kpos <= qpos)
        if window is not None:
            mask &= kpos > qpos - window
        # the mask is head-independent: broadcast over [KV, grp]
        maskf = jnp.broadcast_to(mask[None, None], (kv, grp, bq, bk)
                                 ).reshape(kv, grp * bq, bk)
        s = jnp.where(maskf, s, NEG_INF)

        m_prev = m_scr[...]                                # [KV, grp*bq]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=2))
        p = jnp.where(maskf, jnp.exp(s - m_new[..., None]), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        acc_scr[...] = acc_scr[...] * alpha[..., None] + jax.lax.dot_general(
            p, v, (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=2)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[...]
        safe = jnp.where(l > 0, l, 1.0)   # fully-masked rows -> zeros
        o_ref[0] = (acc_scr[...] / safe[..., None]
                    ).reshape(o_ref.shape[1:]).astype(o_ref.dtype)


def ragged_prefill_attention_bhsd(q, k, v, pos0, take, *,
                                  window: Optional[int] = None,
                                  bq: int = 128, bk: int = 256,
                                  interpret: bool = True):
    """q [G,H,Sq,hd]; k/v [G,KV,W,hd]; pos0/take [G] -> o [G,H,Sq,hd]."""
    G, H, Sq, hd = q.shape
    KV, W = k.shape[1], k.shape[2]
    grp = H // KV
    bq = min(bq, max(Sq, 8))
    bk = min(bk, max(W, 8))
    pq = (-Sq) % bq
    pk = (-W) % bk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0)))
    nq = (Sq + pq) // bq
    nk = (W + pk) // bk

    kernel = functools.partial(
        _ragged_prefill_kernel, scale=1.0 / math.sqrt(hd), window=window,
        bq=bq, bk=bk, grp=grp)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(G, nq, nk),
        in_specs=[
            # q revisited across the KV walk (index constant in j): fetched
            # once per (g, qi), resident while k/v stream underneath it
            pl.BlockSpec((1, H, bq, hd),
                         lambda g, i, j, pos0, take: (g, 0, i, 0)),
            pl.BlockSpec((1, KV, bk, hd),
                         lambda g, i, j, pos0, take: (g, 0, j, 0)),
            pl.BlockSpec((1, KV, bk, hd),
                         lambda g, i, j, pos0, take: (g, 0, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, H, bq, hd),
                               lambda g, i, j, pos0, take: (g, 0, i, 0)),
        scratch_shapes=[
            pltpu.VMEM((KV, grp * bq), jnp.float32),
            pltpu.VMEM((KV, grp * bq), jnp.float32),
            pltpu.VMEM((KV, grp * bq, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, H, Sq + pq, hd), q.dtype),
        compiler_params=tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(pos0, jnp.int32), jnp.asarray(take, jnp.int32), q, k, v)
    return out[:, :, :Sq]
