"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table cloud-scale executor).

[arXiv:2501.kimi2] 61L d_model=7168 64H (GQA kv=8) d_ff=2048 (per expert)
vocab=163840, MoE 384 experts top-8 (+1 shared expert). head_dim is set to
128 (hardware-aligned MXU tile; 7168/64=112 would misalign the systolic
array — noted in DESIGN.md as a TPU adaptation).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    rope_theta=1_000_000.0,
    citation="arXiv:2501.kimi2",
)
