"""xlstm-350m — recurrent xLSTM (sLSTM + mLSTM blocks, attention-free).

[arXiv:2405.04517] 24L d_model=1024 4H (kv=4) d_ff=0 vocab=50304.
Blocks alternate mLSTM (matrix memory, chunked-parallel gated linear
recurrence) and sLSTM (scalar memory, sequential lax.scan) at the
configured ratio. No attention => O(1) decode state; long_500k runs.
Deviation from the paper's exponential-gate stabilizer: we use
sigmoid input gates (bounded, no m-state) — recorded in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    ssm_expand=2,
    ssm_head_dim=256,  # d_inner=2048 over 8 effective heads... per-block heads=4
    mlstm_per_slstm=3,
    citation="arXiv:2405.04517",
)
