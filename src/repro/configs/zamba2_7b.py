"""zamba2-7b — hybrid Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242] 81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000,
ssm_state=64. Mamba2 layers with ONE shared-parameter attention+MLP block
applied every ``attn_every`` layers (Zamba2's shared transformer block);
the shared block's weights live outside the scanned Mamba stack.
long_500k runs: SSM state is O(1) in sequence length and the shared
attention uses a rolling window at decode.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    attn_every=6,
    long_context_window=4096,
    rope_theta=10_000.0,
    citation="arXiv:2411.15242",
)
