"""qwen2-1.5b — dense GQA decoder with QKV bias.

[arXiv:2407.10671] 28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936,
qkv_bias=True. For long_500k decode we serve a sliding-window variant
(long_context_window=4096), per DESIGN.md §4.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    long_context_window=4096,
    rope_theta=1_000_000.0,
    citation="arXiv:2407.10671",
)
