"""qwen3-4b — dense GQA decoder with QK-norm.

[hf:Qwen/Qwen3-8B] (family spec) 36L d_model=2560 32H (GQA kv=8)
d_ff=9728 vocab=151936, qk_norm=True, head_dim=128 (Qwen3 uses explicit
head_dim 128 independent of d_model/n_heads).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    citation="hf:Qwen/Qwen3-8B",
)
