"""whisper-medium — encoder-decoder audio model (conv frontend stubbed).

[arXiv:2212.04356] 24L (decoder; +24L encoder) d_model=1024 16H (kv=16, MHA)
d_ff=4096 vocab=51865. The mel-spectrogram + 2x conv1d frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings (B, 1500, d_model).
GELU MLP as in the paper. vocab 51865 is padded to 51968 for clean
model-axis sharding (see DESIGN.md §5).

Decode shapes: decode_32k runs (self-attn KV cache over generated tokens +
cross-attn to the fixed 1500-frame encoder memory). long_500k is skipped —
full attention and transcript-bounded decode length (DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="whisper-medium",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51865,
    is_encoder_decoder=True,
    n_encoder_layers=24,
    encoder_seq=1500,
    activation="gelu",
    rope_theta=10_000.0,
    citation="arXiv:2212.04356",
)
