"""Config system for repro executor architectures.

Every assigned architecture gets one file in this package defining
``CONFIG = ModelConfig(...)`` with the exact public-literature spec
(cited in ``citation``) plus a ``reduced()`` smoke-test variant
(<=2 layers, d_model<=512, <=4 experts) runnable on CPU.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, replace
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class ModelConfig:
    """A single executor architecture.

    ``family`` selects the block stack:
      dense  : attention + SwiGLU MLP
      moe    : attention + mixture-of-experts MLP
      ssm    : xLSTM (alternating mLSTM / sLSTM blocks, no attention)
      hybrid : Mamba2 backbone with shared attention blocks interleaved
      vlm    : dense decoder consuming text tokens + stub patch embeddings
      audio  : encoder-decoder consuming stub frame embeddings (whisper)
    """

    arch_id: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    citation: str = ""

    # attention details
    head_dim: Optional[int] = None  # default: d_model // n_heads
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    sliding_window: Optional[int] = None  # tokens; None = full attention

    # MoE
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01

    # SSM / hybrid
    ssm_state: int = 0          # Mamba2 N
    ssm_expand: int = 2         # d_inner = expand * d_model
    ssm_head_dim: int = 64      # Mamba2 P
    ssm_conv: int = 4           # depthwise conv width
    attn_every: int = 6         # hybrid: one shared attn block per this many layers
    # xLSTM: ratio of mLSTM blocks per sLSTM block (paper uses mostly mLSTM)
    mlstm_per_slstm: int = 3

    # encoder-decoder (audio)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq: int = 1500     # whisper: 30s audio -> 1500 frames
    activation: str = "swiglu"  # swiglu | gelu

    # modality frontend stubs
    n_image_patches: int = 0    # vlm: patch embeddings prepended to text

    # misc
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # serving variant knobs (e.g. SWA variant for long-context decode on
    # otherwise-full-attention dense archs)
    long_context_window: Optional[int] = None

    # ---- §Perf optimization knobs (beyond-paper; see EXPERIMENTS.md) ----
    # blocked (flash-style) attention at the XLA level: q-block size
    attention_block_q: Optional[int] = None
    # constrain attention q/out to shard the *sequence* dim on the model
    # axis (context parallelism): balances flops when n_heads doesn't
    # divide the model axis
    shard_attn_seq: bool = False
    # store the KV cache with GQA heads expanded to this count so the
    # model-axis shards align with the q-head groups (kills per-layer
    # cache re-gather at decode)
    kv_cache_expand_heads: Optional[int] = None
    # MoE dispatch implementation: "gather" (sorted capacity dispatch,
    # GSPMD-global) or "ep" (shard_map expert parallelism)
    moe_impl: str = "gather"
    # decode: thread the KV cache through the layer scan as a carry with
    # in-place dynamic-update-slice instead of xs/ys double buffering
    # (kills the full-cache copy per decode step)
    carry_cache: bool = False

    def __post_init__(self):
        if self.family not in ("dense", "moe", "ssm", "hybrid", "vlm", "audio"):
            raise ValueError(f"unknown family {self.family!r}")
        if self.family == "moe" and (self.n_experts <= 0 or self.top_k <= 0):
            raise ValueError("moe family requires n_experts/top_k")
        if self.family == "audio" and not self.is_encoder_decoder:
            raise ValueError("audio family must be encoder-decoder")

    # ---- derived ------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.n_heads

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.resolved_head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.resolved_head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width (Mamba2 / xLSTM up-projection)."""
        return self.ssm_expand * self.d_model

    @property
    def n_ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def padded_vocab(self, multiple: int = 256) -> int:
        """Vocab padded for clean model-axis sharding (whisper: 51865->51968)."""
        return _round_up(self.vocab_size, multiple)

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def supports_long_context(self) -> bool:
        """True iff decode at 524k tokens is sub-quadratic for this config."""
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window is not None or self.long_context_window is not None

    def decode_window(self) -> Optional[int]:
        """Effective attention window used for rolling-buffer decode caches."""
        if self.sliding_window is not None:
            return self.sliding_window
        return self.long_context_window

    # ---- parameter count (analytic; used by roofline MODEL_FLOPS) -----
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * D * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            # xLSTM blocks: up/gate/down projections + gates (approximate,
            # matches init in models/ssm.py)
            di = self.d_inner
            per_m = D * di * 2 + di * D + 4 * di * D // self.ssm_expand  # mLSTM
            per_s = 4 * (D * D + (D // max(self.n_heads, 1)) * D)        # sLSTM approx
            n_s = self.n_layers // (self.mlstm_per_slstm + 1)
            n_m = self.n_layers - n_s
            return emb + n_m * per_m + n_s * per_s
        attn = D * self.q_dim + 2 * D * self.kv_dim + self.q_dim * D
        if self.family == "hybrid":
            di = self.d_inner
            N, H = self.ssm_state, self.n_ssm_heads
            per_mamba = D * (2 * di + 2 * N * 1 + H) + di * D + self.ssm_conv * di
            n_attn = self.n_layers // self.attn_every
            n_mamba = self.n_layers - n_attn
            mlp = 3 * D * F
            return emb + n_mamba * per_mamba + n_attn * (attn + mlp)
        if self.family == "moe":
            per_expert = 3 * D * F
            n_e = self.top_k + self.n_shared_experts if active_only else (
                self.n_experts + self.n_shared_experts)
            mlp = n_e * per_expert + D * self.n_experts  # + router
        else:
            n_mlp = 3 if self.activation == "swiglu" else 2
            mlp = n_mlp * D * F
        dec = self.n_layers * (attn + mlp)
        enc = 0
        if self.is_encoder_decoder:
            cross = attn  # cross-attention block per decoder layer
            dec += self.n_layers * cross
            enc = self.n_encoder_layers * (attn + mlp)
        return emb + dec + enc

    # ---- reduced smoke variant ----------------------------------------
    def reduced(self) -> "ModelConfig":
        """<=2 layers, d_model<=512, <=4 experts — CPU-runnable smoke config."""
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        head_dim = d_model // n_heads
        n_kv = min(self.n_kv_heads, n_heads)
        while n_heads % n_kv:
            n_kv -= 1
        kw = dict(
            n_layers=2,
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=n_kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            ssm_head_dim=min(self.ssm_head_dim, 32),
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            attn_every=2,
            n_image_patches=min(self.n_image_patches, 16) if self.n_image_patches else 0,
            encoder_seq=min(self.encoder_seq, 32),
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else None,
            long_context_window=(min(self.long_context_window, 64)
                                 if self.long_context_window else None),
            mlstm_per_slstm=1,
        )
        if self.n_experts:
            kw.update(n_experts=min(self.n_experts, 4), top_k=min(self.top_k, 2))
        return replace(self, **kw)

    def variant(self, **kw) -> "ModelConfig":
        return replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    """One of the assigned benchmark input shapes."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def fields_summary(cfg: ModelConfig) -> str:
    keep = ("arch_id", "family", "n_layers", "d_model", "n_heads", "n_kv_heads",
            "d_ff", "vocab_size", "n_experts", "top_k", "ssm_state")
    d = {f.name: getattr(cfg, f.name) for f in dataclasses.fields(cfg)}
    return ", ".join(f"{k}={d[k]}" for k in keep if d.get(k))
