"""mistral-large-123b — dense decoder.

[hf:mistralai/Mistral-Large-Instruct-2407] 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768. Full attention; for the long_500k decode shape we
serve an explicit sliding-window variant (long_context_window=4096 rolling
KV buffer), a beyond-paper serving adaptation noted in DESIGN.md.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mistral-large-123b",
    family="dense",
    n_layers=88,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=32768,
    rope_theta=1_000_000.0,
    long_context_window=4096,
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
)
