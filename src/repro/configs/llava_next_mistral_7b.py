"""llava-next-mistral-7b — VLM: Mistral-7B language backbone + anyres vision stub.

[hf:llava-hf/llava-v1.6-mistral-7b-hf] 32L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=32000. The vision tower (CLIP ViT-L/336 + anyres tiling +
2-layer MLP projector) is a STUB per the assignment: ``input_specs()``
provides precomputed patch embeddings of shape (B, n_patches, d_model).
LLaVA-NeXT anyres uses up to 5 tiles x 576 patches; we provision the base
576-patch grid as the prepended multimodal prefix.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1_000_000.0,
    n_image_patches=576,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)
