"""Architecture config registry.

``get_config(arch_id)`` resolves any assigned architecture (plus the
HybridFlow paper's own edge/cloud executor stand-ins) to a ModelConfig.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

__all__ = ["ModelConfig", "InputShape", "SHAPES", "ARCH_IDS", "get_config",
           "all_configs", "PAPER_EDGE_ARCH", "PAPER_CLOUD_ARCH",
           "SWAP_EDGE_ARCH", "SWAP_CLOUD_ARCH"]

from repro.configs.base import ModelConfig, InputShape, SHAPES

_MODULES = {
    "llava-next-mistral-7b": "repro.configs.llava_next_mistral_7b",
    "mistral-large-123b": "repro.configs.mistral_large_123b",
    "mixtral-8x7b": "repro.configs.mixtral_8x7b",
    "whisper-medium": "repro.configs.whisper_medium",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "xlstm-350m": "repro.configs.xlstm_350m",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "qwen3-4b": "repro.configs.qwen3_4b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
}

ARCH_IDS: List[str] = list(_MODULES)

_cache: Dict[str, ModelConfig] = {}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id in _cache:
        return _cache[arch_id]
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    cfg = importlib.import_module(_MODULES[arch_id]).CONFIG
    assert cfg.arch_id == arch_id, (cfg.arch_id, arch_id)
    _cache[arch_id] = cfg
    return cfg


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


# The HybridFlow paper's own executor pair, mapped onto the assigned pool
# (DESIGN.md §3): edge SLM <- qwen2-1.5b-class model, cloud LLM <- the
# largest assigned executor. Used by examples and the serving engine.
PAPER_EDGE_ARCH = "qwen2-1.5b"
PAPER_CLOUD_ARCH = "mistral-large-123b"
# Model-pair swap experiment (paper App. D.2).
SWAP_EDGE_ARCH = "internlm2-1.8b"
SWAP_CLOUD_ARCH = "mixtral-8x7b"
