"""Checkpointing: pytree <-> directory of .npz shards + JSON manifest.

No orbax dependency. Leaves are saved with their path-derived keys; restore
validates structure and dtypes. Works for params, optimizer state, and the
HybridFlow router head.
"""
from __future__ import annotations

import json
import os
import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(path: str, tree, *, step: Optional[int] = None,
                    shard_mb: int = 512) -> None:
    os.makedirs(path, exist_ok=True)
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}
    manifest = {"step": step, "keys": {}, "shards": []}
    shard, shard_bytes, shard_idx = {}, 0, 0

    def flush():
        nonlocal shard, shard_bytes, shard_idx
        if not shard:
            return
        name = f"shard_{shard_idx:04d}.npz"
        np.savez(os.path.join(path, name), **shard)
        manifest["shards"].append(name)
        shard_idx += 1
        shard, shard_bytes = {}, 0

    for k, v in sorted(flat.items()):
        safe = re.sub(r"[^\w\[\]/.-]", "_", k)
        manifest["keys"][k] = {"shard": shard_idx, "safe": safe,
                               "shape": list(v.shape), "dtype": str(v.dtype)}
        shard[safe] = v
        shard_bytes += v.nbytes
        if shard_bytes > shard_mb * 2 ** 20:
            flush()
    flush()
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)


def restore_checkpoint(path: str, template) -> Tuple[Any, Optional[int]]:
    """Restore into the structure of ``template`` (shapes/dtypes validated)."""
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    arrays: Dict[str, np.ndarray] = {}
    loaded_shards = {}
    for k, meta in manifest["keys"].items():
        sh = manifest["shards"][meta["shard"]]
        if sh not in loaded_shards:
            loaded_shards[sh] = np.load(os.path.join(path, sh))
        arrays[k] = loaded_shards[sh][meta["safe"]]
    flat_t = _flatten(template)
    if set(flat_t) != set(arrays):
        missing = set(flat_t) ^ set(arrays)
        raise ValueError(f"checkpoint structure mismatch: {sorted(missing)[:8]}")
    leaves, treedef = jax.tree_util.tree_flatten(template)
    keys = [
        "/".join(_path_str(p) for p in path_)
        for path_, _ in jax.tree_util.tree_flatten_with_path(template)[0]
    ]
    new_leaves = []
    for key, leaf in zip(keys, leaves):
        arr = arrays[key]
        if tuple(arr.shape) != tuple(np.shape(leaf)):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {np.shape(leaf)}")
        new_leaves.append(jax.numpy.asarray(arr, dtype=leaf.dtype))
    return treedef.unflatten(new_leaves), manifest.get("step")


def latest_checkpoint(root: str) -> Optional[str]:
    if not os.path.isdir(root):
        return None
    cands = [d for d in os.listdir(root) if d.startswith("ckpt_")]
    if not cands:
        return None
    return os.path.join(root, max(cands, key=lambda d: int(d.split("_")[1])))
