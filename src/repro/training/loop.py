"""Training loop: jitted train step + host-side driver with checkpointing.

``make_train_step(cfg)`` builds the pure step function the dry-run lowers
on the production mesh; ``train(...)`` is the host driver used by
examples/train_lm.py (single-device CPU in this container).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update
from repro.training import checkpoint as CKPT


@dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = field(default_factory=AdamWConfig)
    remat: bool = False
    remat_policy: Optional[str] = None   # None | "dots" | "nothing"
    log_every: int = 10
    ckpt_every: int = 0                  # 0 = disabled
    ckpt_dir: str = "/tmp/repro_ckpt"


def make_train_step(cfg: ModelConfig, tcfg: Optional[TrainConfig] = None
                    ) -> Callable:
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    tcfg = tcfg or TrainConfig()

    def step(params, opt_state: AdamWState, batch):
        def loss(p):
            return M.loss_fn(p, cfg, batch, remat=tcfg.remat,
                             remat_policy=tcfg.remat_policy)

        (lv, parts), grads = jax.value_and_grad(loss, has_aux=True)(params)
        params, opt_state, om = adamw_update(tcfg.opt, grads, opt_state, params)
        metrics = {"loss": lv, **parts, **om}
        return params, opt_state, metrics

    return step


def init_train_state(cfg: ModelConfig, key, *, dtype=None):
    params = M.init_params(cfg, key, dtype=dtype)
    return params, adamw_init(params)


def train(cfg: ModelConfig, data_iter: Iterator[Dict[str, Any]], *,
          steps: int, tcfg: Optional[TrainConfig] = None, seed: int = 0,
          dtype=jnp.float32, params=None, opt_state=None,
          log_fn: Callable[[str], None] = print) -> Tuple[Any, AdamWState, list]:
    tcfg = tcfg or TrainConfig()
    key = jax.random.PRNGKey(seed)
    if params is None:
        params, opt_state = init_train_state(cfg, key, dtype=dtype)
    step_fn = jax.jit(make_train_step(cfg, tcfg))
    history = []
    t0 = time.time()
    for i in range(steps):
        batch = next(data_iter)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if i % tcfg.log_every == 0 or i == steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = i
            m["wall_s"] = time.time() - t0
            history.append(m)
            log_fn(f"step {i:5d} loss {m['loss']:.4f} ce {m['ce']:.4f} "
                   f"gnorm {m['grad_norm']:.3f} lr {m['lr']:.2e}")
        if tcfg.ckpt_every and i and i % tcfg.ckpt_every == 0:
            CKPT.save_checkpoint(f"{tcfg.ckpt_dir}/ckpt_{i}",
                                 {"params": params, "opt": opt_state}, step=i)
    return params, opt_state, history
