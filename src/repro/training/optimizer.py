"""Optimizers from scratch (no optax): AdamW with decoupled weight decay,
global-norm gradient clipping, and LR schedules. State is a pytree shaped
like the params, so it shards identically under pjit.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray       # int32 scalar
    mu: object              # first moment pytree
    nu: object              # second moment pytree


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"   # constant | cosine | linear_warmup_cosine
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    if cfg.schedule == "constant":
        return jnp.asarray(cfg.lr, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "linear_warmup_cosine" or cfg.schedule == "cosine":
        t = jnp.clip((step - cfg.warmup_steps) /
                     jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(math.pi * t))
        frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
        return cfg.lr * warm * frac
    raise ValueError(cfg.schedule)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype) if False else
                        (g.astype(jnp.float32) * scale).astype(g.dtype), tree), norm


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), p)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros(params), nu=zeros(params))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state.step + 1
    lr = schedule_lr(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        st_dtype = m.dtype  # moments may be bf16 (§Perf opt_bf16)
        m = b1 * m.astype(jnp.float32) + (1 - b1) * g32
        v = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only (norms/biases exempt)
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m.astype(st_dtype), v.astype(st_dtype)

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}


def sgd_update(lr: float, grads, params):
    """Plain SGD (used by the router warm-start tests)."""
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
