"""Open-loop traffic: seeded arrival schedules and a replayable trace format.

Closed-loop benchmarks admit every query at t=0 and measure makespan;
a service under real traffic sees *arrivals* — bursty Poisson streams
with diurnal ramps, peaks, and zero-traffic gaps.  This module generates
those arrival schedules deterministically from a seed and packages them
as a :class:`Trace` that ``ServingRuntime.serve_trace`` can replay.

The rate profile is a sequence of :class:`Phase` segments (flat rate or
linear ramp); arrivals are drawn from the resulting non-homogeneous
Poisson process by thinning: sample a homogeneous process at the peak
rate, keep each point with probability ``rate(t) / rate_max``.  Same
seed + same phases => bit-identical schedule.

A :class:`Trace` is immutable and replayable: it round-trips through
JSON (``to_json`` / ``from_json``), and ``scaled()`` compresses the
wall-clock so a 60 s logical trace replays in a few seconds of test
time while keeping the same arrival *pattern*.
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "Phase",
    "Trace",
    "day_cycle",
]


@dataclass(frozen=True)
class Phase:
    """One segment of a rate profile.

    ``rps`` is the arrival rate at the start of the phase; when
    ``rps_end`` is set the rate ramps linearly to it over ``duration``
    seconds, otherwise the phase is flat.  ``rps=0`` models a
    zero-traffic gap.
    """

    duration: float
    rps: float
    rps_end: Optional[float] = None

    def __post_init__(self):
        if self.duration <= 0:
            raise ValueError(f"Phase duration must be > 0, got {self.duration}")
        if self.rps < 0 or (self.rps_end is not None and self.rps_end < 0):
            raise ValueError("Phase rates must be >= 0")

    @property
    def peak(self) -> float:
        return max(self.rps, self.rps if self.rps_end is None else self.rps_end)

    @property
    def mean_rps(self) -> float:
        if self.rps_end is None:
            return self.rps
        return 0.5 * (self.rps + self.rps_end)

    def rate_at(self, t: float) -> float:
        """Instantaneous rate ``t`` seconds into this phase."""
        if self.rps_end is None:
            return self.rps
        frac = min(max(t / self.duration, 0.0), 1.0)
        return self.rps + (self.rps_end - self.rps) * frac


def day_cycle(*, base_rps: float, peak_rps: float,
              duration: float = 86400.0) -> Tuple[Phase, ...]:
    """A compressed diurnal profile: night trough, morning ramp, midday
    peak, evening decay back to the base rate, late-night gap.

    The segment fractions are fixed so the same (base, peak, duration)
    always yields the same profile; pass the result to
    :meth:`Trace.from_phases`.
    """
    if peak_rps < base_rps:
        raise ValueError("peak_rps must be >= base_rps")
    d = float(duration)
    return (
        Phase(0.20 * d, base_rps),                       # night trough
        Phase(0.15 * d, base_rps, rps_end=peak_rps),     # morning ramp
        Phase(0.25 * d, peak_rps),                       # midday peak
        Phase(0.20 * d, peak_rps, rps_end=base_rps),     # evening decay
        Phase(0.20 * d, base_rps),                       # late evening
    )


def _thin(phases: Sequence[Phase], seed: int) -> List[float]:
    """Non-homogeneous Poisson arrivals over ``phases`` by thinning."""
    rate_max = max((p.peak for p in phases), default=0.0)
    horizon = sum(p.duration for p in phases)
    if rate_max <= 0.0 or horizon <= 0.0:
        return []
    # phase lookup by cumulative start time
    starts: List[float] = []
    acc = 0.0
    for p in phases:
        starts.append(acc)
        acc += p.duration

    def rate_at(t: float) -> float:
        # phases are few; linear scan keeps this dependency-free
        for start, p in zip(reversed(starts), reversed(phases)):
            if t >= start:
                return p.rate_at(t - start)
        return phases[0].rate_at(t)

    rng = np.random.default_rng(seed)
    arrivals: List[float] = []
    t = 0.0
    while True:
        t += float(rng.exponential(1.0 / rate_max))
        if t >= horizon:
            break
        if float(rng.random()) * rate_max < rate_at(t):
            arrivals.append(t)
    return arrivals


@dataclass(frozen=True)
class Trace:
    """A replayable arrival schedule.

    ``arrivals`` are seconds from trace start, sorted ascending.
    ``target_rps`` is the *nominal* mean rate of the generating profile
    (integral of the rate over the horizon divided by the horizon);
    ``mean_rps`` is what the draw actually realised.
    """

    arrivals: Tuple[float, ...]
    duration: float
    seed: int = 0
    target_rps: Optional[float] = None
    label: str = "trace"
    phases: Tuple[Phase, ...] = field(default=(), repr=False)

    def __post_init__(self):
        arr = tuple(float(a) for a in self.arrivals)
        if any(b < a for a, b in zip(arr, arr[1:])):
            arr = tuple(sorted(arr))
        object.__setattr__(self, "arrivals", arr)
        if self.duration <= 0:
            raise ValueError("Trace duration must be > 0")

    # -- shape ---------------------------------------------------------
    def __len__(self) -> int:
        return len(self.arrivals)

    def __iter__(self):
        return iter(self.arrivals)

    @property
    def n(self) -> int:
        return len(self.arrivals)

    @property
    def mean_rps(self) -> float:
        """Realised mean arrival rate over the trace horizon."""
        return self.n / self.duration

    def largest_gap(self) -> float:
        """Longest inter-arrival gap (including the leading/trailing
        edges of the horizon) — the window an autoscaler can go idle in."""
        if not self.arrivals:
            return self.duration
        pts = (0.0,) + self.arrivals + (self.duration,)
        return max(b - a for a, b in zip(pts, pts[1:]))

    # -- construction --------------------------------------------------
    @classmethod
    def poisson(cls, rps: float, duration: float, *, seed: int = 0,
                label: str = "poisson") -> "Trace":
        """Homogeneous Poisson arrivals at ``rps`` for ``duration`` s."""
        return cls.from_phases([Phase(duration, rps)], seed=seed, label=label)

    @classmethod
    def from_phases(cls, phases: Sequence[Phase], *, seed: int = 0,
                    label: str = "phased") -> "Trace":
        """Non-homogeneous Poisson arrivals over a phase profile."""
        phases = tuple(phases)
        if not phases:
            raise ValueError("need at least one Phase")
        horizon = sum(p.duration for p in phases)
        target = sum(p.mean_rps * p.duration for p in phases) / horizon
        return cls(arrivals=tuple(_thin(phases, seed)), duration=horizon,
                   seed=seed, target_rps=target, label=label, phases=phases)

    @classmethod
    def bursty(cls, *, base_rps: float, duration: float, burst_rps: float,
               burst_at: float, burst_s: float, gap_at: Optional[float] = None,
               gap_s: float = 0.0, seed: int = 0,
               label: str = "bursty") -> "Trace":
        """Flat base traffic with one burst and an optional dead gap.

        Segments must fit inside ``duration`` in the order
        base | burst | base | gap | base; the gap (rate 0) must start
        after the burst ends.
        """
        marks = [(burst_at, burst_s, burst_rps)]
        if gap_at is not None and gap_s > 0:
            if gap_at < burst_at + burst_s:
                raise ValueError("gap must start after the burst ends")
            marks.append((gap_at, gap_s, 0.0))
        phases: List[Phase] = []
        t = 0.0
        for at, length, rate in marks:
            if at < t or at + length > duration:
                raise ValueError("burst/gap segment outside the trace horizon")
            if at > t:
                phases.append(Phase(at - t, base_rps))
            phases.append(Phase(length, rate))
            t = at + length
        if t < duration:
            phases.append(Phase(duration - t, base_rps))
        return cls.from_phases(phases, seed=seed, label=label)

    # -- transforms ----------------------------------------------------
    def scaled(self, factor: float) -> "Trace":
        """Compress (factor < 1) or stretch the wall-clock while keeping
        the arrival pattern: times and duration scale by ``factor``,
        rates by ``1/factor``."""
        if factor <= 0:
            raise ValueError("scale factor must be > 0")
        return replace(
            self,
            arrivals=tuple(a * factor for a in self.arrivals),
            duration=self.duration * factor,
            target_rps=None if self.target_rps is None
            else self.target_rps / factor,
            label=f"{self.label}@x{factor:g}",
            phases=tuple(
                Phase(p.duration * factor, p.rps / factor,
                      None if p.rps_end is None else p.rps_end / factor)
                for p in self.phases),
        )

    # -- persistence ---------------------------------------------------
    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "label": self.label,
            "seed": self.seed,
            "duration": self.duration,
            "target_rps": self.target_rps,
            "arrivals": list(self.arrivals),
            "phases": [[p.duration, p.rps, p.rps_end] for p in self.phases],
        }
        text = json.dumps(payload, indent=1)
        if path is not None:
            with open(path, "w") as f:
                f.write(text + "\n")
        return text

    @classmethod
    def from_json(cls, source) -> "Trace":
        """Load a trace from a JSON string or a path to a JSON file."""
        if not isinstance(source, str) or "{" not in source:
            with open(source) as f:
                source = f.read()
        d = json.loads(source)
        return cls(arrivals=tuple(d["arrivals"]), duration=d["duration"],
                   seed=d.get("seed", 0), target_rps=d.get("target_rps"),
                   label=d.get("label", "trace"),
                   phases=tuple(Phase(*p) for p in d.get("phases", ())))

    def describe(self) -> str:
        tgt = "-" if self.target_rps is None else f"{self.target_rps:.2f}"
        return (f"trace[{self.label}] n={self.n} dur={self.duration:.1f}s "
                f"target={tgt} rps measured={self.mean_rps:.2f} rps "
                f"max_gap={self.largest_gap():.1f}s")
