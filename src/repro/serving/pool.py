"""Replicated serving-engine pool: sharded cloud capacity with
replica-aware dispatch.

HybridFlow's cloud side was one ``ServingEngine`` with N slots, so
"cloud concurrency" was really a single replica's batch width. An
``EnginePool`` owns R engine replicas — **shared params** (one pytree,
no re-init) but **independent KV slot pools** — and exposes the same
``submit`` / ``has_work`` / ``step`` surface as a single engine, so
``JAXExecutor`` and the fleet scheduler drive either interchangeably.

Dispatch contract
-----------------
* **Least-loaded replica selection** — ``submit`` routes each request to
  the replica with the smallest *load* (active + queued requests); ties
  break to the lowest replica index. Selection is a pure function of the
  pool's current occupancy, so a given submit/step sequence is
  deterministic.
* **R = 1 identity** — a one-replica pool performs exactly the single
  engine's admit → prefill → decode sequence per ``step``; greedy tokens
  are bit-identical to driving the lone ``ServingEngine`` directly
  (tested through the live ``FleetScheduler``).
* **Pump pass** — ``pump()``/``step()`` advance *every* replica with
  pending work in one pass. With ``threads=True`` (default) each loaded
  replica's step runs on its own worker thread: jitted execution
  releases the GIL, so replica computes overlap on multi-core hosts the
  way they would on per-replica accelerators — two half-full replicas
  cost one step's wall-clock, not two. Replica state is strictly
  thread-private (each worker touches only its own engine) and finished
  requests are collected in replica-index order, so token streams and
  completion order stay deterministic. ``threads=False`` falls back to
  a sequential launch-all/commit-all pass: all replicas' prefill chunks
  are dispatched before any is synced, then all decode steps likewise,
  letting JAX's async dispatch overlap one replica's host-side commit
  with the next replica's device compute.
* **Saturation** — ``all_saturated`` is True only when every replica's
  load has reached its slot count. ``JAXExecutor.saturated()`` forwards
  it to the fleet scheduler, whose cloud→edge spill fires only then:
  a pool with any free replica slot keeps cloud-routed work on the
  cloud.
* **Occupancy stats** — ``occupancy()`` reports per-replica slot-lease
  state (active / queued / free / requests / slot_reuses / peak_active /
  health); ``stats`` aggregates the replicas' counters into one
  engine-shaped dict (plus ``replicas`` and ``pump_passes``) for drop-in
  reporting.

Failure semantics
-----------------
Every replica carries a health state: **healthy → suspect → dead**. A
replica whose step *raises* (in the thread pump, the single-loaded fast
path, or any phase of the sequential pass) is marked **dead**: the
exception is captured — never lost in a worker thread, never allowed to
strand sibling replicas' finished requests — and with ``failover=True``
(default) the dead replica's in-flight work (active slots in slot order,
then queue FIFO) is re-submitted to the least-loaded survivor, restarted
from the prompt (decoded tokens are discarded; generation state lives in
the replica's KV slots, which died with it). With ``failover=False`` the
captured exception re-raises from ``step`` instead. When every replica
is dead, ``step``/``submit`` raise. ``suspect_after=N`` arms straggler
detection: a replica that holds work but makes no progress for N
consecutive pool passes turns **suspect** — its work is hedged onto
strictly-healthy replicas and dispatch deprioritizes it until it makes
progress again (suspect is reversible; dead is not). All transitions
land in ``pool_stats`` (deaths / failovers / suspects / hedges /
replica_errors).

``EnginePool.replicate`` builds R fresh replicas from a config + params;
``EnginePool.like`` scales out an existing engine, keeping it as replica
0 (external handles to it stay live) and cloning R-1 siblings with
distinct sampling seeds.

Elasticity
----------
``arm_autoscale(AutoscalePolicy(...))`` makes the pool *elastic*: each
replica carries a lifecycle state — **warm** (serving), **warming**
(paying the modeled cold start), **cold** (scaled down) — orthogonal to
its health state. An :class:`Autoscaler` ticks on the pool's wall clock
(every ``pump``/``step``/``submit``) and:

* **grows** — starts warming a cold replica when live load presses on
  the warm+warming capacity (``load > capacity * scale_up_at``); the
  replica serves only after the modeled :class:`ColdStartModel` phases
  (boot + model load + first inference) have elapsed;
* **shrinks** — cools an idle warm replica when occupancy drops under
  ``scale_down_at`` (never below ``max(min_replicas, 1)`` this way);
* **scales to zero** — with ``min_replicas=0``, a traffic gap longer
  than ``idle_to_zero_s`` cools every warm replica;
* **pokes** — the first ``submit`` after a gap finds no warm/warming
  replica and starts one warming ("poke-to-warm"); the request queues
  on it and waits out the cold start.

Only *warm* replicas step; warming/cold replicas hold queued work
without progress (straggler detection skips them). Scale-down is a
*model*: replicas share one host here, so cooling stops a replica's
passes and charges the re-warm cost without actually releasing its KV
memory — the cost accounting, not the allocator, is the contract.
Every decision lands in ``Autoscaler.events`` / ``pool_stats`` and is
surfaced through the runtime report.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.serving.engine import Request, ServingEngine


@dataclass(frozen=True)
class ColdStartModel:
    """Modeled cost of bringing a cold replica up, split into the three
    phases worth modeling separately (boot, weight load, first-inference
    warm-up/compile); a warming replica serves only after all three."""

    boot_s: float = 0.4
    model_load_s: float = 0.8
    first_infer_s: float = 0.3

    @property
    def total_s(self) -> float:
        return self.boot_s + self.model_load_s + self.first_infer_s


@dataclass(frozen=True)
class AutoscalePolicy:
    """Occupancy-driven elasticity policy for an :class:`EnginePool`.

    ``scale_up_at`` / ``scale_down_at`` are load fractions of the
    current warm+warming slot capacity; ``max_replicas=None`` means the
    pool's full replica count. ``min_replicas=0`` enables scale-to-zero
    after ``idle_to_zero_s`` of an empty pool.
    """

    min_replicas: int = 0
    max_replicas: Optional[int] = None
    scale_up_at: float = 0.8
    scale_down_at: float = 0.25
    idle_to_zero_s: float = 1.0
    decision_interval_s: float = 0.05
    cold_start: ColdStartModel = field(default_factory=ColdStartModel)

    def __post_init__(self):
        if self.min_replicas < 0:
            raise ValueError("min_replicas must be >= 0")
        if not (0.0 < self.scale_up_at <= 1.0):
            raise ValueError("scale_up_at must be in (0, 1]")
        if not (0.0 <= self.scale_down_at < self.scale_up_at):
            raise ValueError("scale_down_at must be in [0, scale_up_at)")


class Autoscaler:
    """Grows/shrinks an :class:`EnginePool`'s warm replica set from live
    occupancy. Pure bookkeeping over the pool's lifecycle list — ticked
    from ``pump``/``step``/``submit``, no thread of its own. ``clock``
    is injectable so tests can drive transitions deterministically."""

    def __init__(self, pool: "EnginePool", policy: AutoscalePolicy,
                 clock: Optional[Callable[[], float]] = None):
        self.pool = pool
        self.policy = policy
        self.clock = clock if clock is not None else time.perf_counter
        self._t0 = self.clock()
        self.events: List[Tuple[float, str, int]] = []  # (t, action, replica)
        self.counters: Dict[str, int] = {
            "scale_ups": 0, "scale_downs": 0, "scale_to_zero": 0,
            "pokes": 0, "promotions": 0}
        self._ready_at: Dict[int, float] = {}
        self._idle_since: Optional[float] = None
        self._last_decision = float("-inf")
        n_warm = min(max(policy.min_replicas, 0), pool.n_replicas)
        for i in range(pool.n_replicas):
            pool.lifecycle[i] = "warm" if i < n_warm else "cold"

    def _now(self) -> float:
        return self.clock() - self._t0

    def _log(self, now: float, action: str, i: int) -> None:
        self.events.append((round(now, 4), action, i))

    def _start_warming(self, i: int, now: float, action: str) -> None:
        self.pool.lifecycle[i] = "warming"
        self._ready_at[i] = now + self.policy.cold_start.total_s
        self.counters["scale_ups"] += 1
        self._log(now, action, i)

    def poke(self) -> Optional[int]:
        """First arrival after a gap: start warming one cold replica so
        the queued request has somewhere to land. Returns its index."""
        pool = self.pool
        cold = [i for i in pool._alive() if pool.lifecycle[i] == "cold"]
        if not cold:
            return None
        self.counters["pokes"] += 1
        self._start_warming(cold[0], self._now(), "poke")
        return cold[0]

    def tick(self) -> None:
        now = self._now()
        pool, p = self.pool, self.policy
        # promotions first: a warming replica whose cold start has
        # elapsed serves from this pass on
        for i in sorted(self._ready_at):
            if pool.health[i] == "dead":
                del self._ready_at[i]
            elif now >= self._ready_at[i]:
                pool.lifecycle[i] = "warm"
                del self._ready_at[i]
                self.counters["promotions"] += 1
                self._log(now, "warm", i)
        load = pool.load
        if load > 0:
            self._idle_since = None
        elif self._idle_since is None:
            self._idle_since = now
        if now - self._last_decision < p.decision_interval_s:
            return
        self._last_decision = now
        alive = pool._alive()
        warm = [i for i in alive if pool.lifecycle[i] == "warm"]
        warming = [i for i in alive if pool.lifecycle[i] == "warming"]
        cold = [i for i in alive if pool.lifecycle[i] == "cold"]
        max_r = p.max_replicas if p.max_replicas is not None \
            else pool.n_replicas
        cap = sum(pool.engines[i].slots for i in warm + warming)
        # grow: pending load pressing on the serving capacity
        if (cold and len(warm) + len(warming) < max_r and load > 0
                and (cap == 0 or load > cap * p.scale_up_at)):
            self._start_warming(cold[0], now, "grow")
            return
        # scale to zero: a traffic gap outlasted idle_to_zero_s
        if (p.min_replicas == 0 and warm and load == 0
                and self._idle_since is not None
                and now - self._idle_since >= p.idle_to_zero_s):
            for i in warm:
                pool.lifecycle[i] = "cold"
                self.counters["scale_downs"] += 1
                self._log(now, "to_zero", i)
            self.counters["scale_to_zero"] += 1
            return
        # shrink: low occupancy, keep at least max(min_replicas, 1) warm
        floor = max(p.min_replicas, 1)
        idle_warm = [i for i in warm if pool.engines[i].load == 0]
        if (len(warm) > floor and idle_warm and cap > 0
                and load < cap * p.scale_down_at):
            i = idle_warm[-1]
            pool.lifecycle[i] = "cold"
            self.counters["scale_downs"] += 1
            self._log(now, "shrink", i)

    def summary(self) -> Dict[str, object]:
        return {"events": list(self.events), **self.counters}


class EnginePool:
    """R serving-engine replicas behind one engine-shaped surface."""

    # Concurrency contract, enforced statically by reprolint's
    # thread-ownership rule (tools/reprolint/README.md). During a
    # threaded step() pass, each replica's worker owns that replica's
    # state (ServingEngine declares it replica-private); everything
    # pool-level below is join-only — read or mutated only by the
    # coordinator thread, with mutations happening at/after the
    # f.result() join barrier. The pool itself runs no worker-thread
    # methods (workers execute ServingEngine.step), and step() is the
    # one method during which workers are live (_CONCURRENT_METHODS is
    # deliberately not closed over callees: _kill_replica /
    # _update_health / _hedge_from run after the join barrier).
    _THREAD_OWNERSHIP = {
        "engines": "join-only",
        "health": "join-only",
        "lifecycle": "join-only",
        "pool_stats": "join-only",
        "autoscaler": "join-only",
        "_tp": "join-only",
        "_last_progress": "join-only",
        "_stalled_passes": "join-only",
    }
    _WORKER_METHODS = ()
    _CONCURRENT_METHODS = ("step",)

    def __init__(self, engines: Sequence[ServingEngine], *,
                 threads: bool = True, failover: bool = True,
                 suspect_after: Optional[int] = None,
                 autoscale: Optional[AutoscalePolicy] = None):
        if not engines:
            raise ValueError("EnginePool needs at least one replica")
        self.engines: List[ServingEngine] = list(engines)
        self.threads = threads
        self.failover = failover
        self.suspect_after = suspect_after
        self.health: List[str] = ["healthy"] * len(self.engines)
        # lifecycle (warm/warming/cold) is orthogonal to health; without
        # an autoscaler every replica is permanently warm
        self.lifecycle: List[str] = ["warm"] * len(self.engines)
        self.autoscaler: Optional[Autoscaler] = None
        if autoscale is not None:
            self.arm_autoscale(autoscale)
        self._tp: Optional[ThreadPoolExecutor] = None
        self._last_progress = [-1] * len(self.engines)
        self._stalled_passes = [0] * len(self.engines)
        self.pool_stats: Dict[str, object] = {
            "pump_passes": 0,
            "submitted": [0] * len(self.engines),
            "deaths": 0,
            "failovers": 0,
            "suspects": 0,
            "hedges": 0,
            "replica_errors": [],
        }

    # ---- constructors --------------------------------------------------
    @classmethod
    def replicate(cls, cfg, params, *, replicas: int, seed: int = 0,
                  threads: bool = True, failover: bool = True,
                  suspect_after: Optional[int] = None,
                  autoscale: Optional[AutoscalePolicy] = None,
                  **engine_kw) -> "EnginePool":
        """R fresh replicas sharing one params pytree. Replica i samples
        with ``seed + i`` so replica 0 matches a lone engine built with
        ``seed`` (the R=1 bit-identity guarantee)."""
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        return cls([ServingEngine(cfg, params, seed=seed + i, **engine_kw)
                    for i in range(replicas)], threads=threads,
                   failover=failover, suspect_after=suspect_after,
                   autoscale=autoscale)

    @classmethod
    def like(cls, engine: ServingEngine, replicas: int, *,
             threads: bool = True) -> "EnginePool":
        """Scale an existing engine out to R replicas: the given engine
        becomes replica 0 (its queue/slots are preserved), siblings are
        clones over the same params with distinct sampling seeds."""
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        return cls([engine] + [engine.clone(seed=engine.seed + i)
                               for i in range(1, replicas)],
                   threads=threads)

    # ---- elasticity ----------------------------------------------------
    def arm_autoscale(self, policy: AutoscalePolicy, *,
                      clock: Optional[Callable[[], float]] = None
                      ) -> Autoscaler:
        """Attach an :class:`Autoscaler`: replicas beyond
        ``policy.min_replicas`` start cold and are warmed on demand."""
        self.autoscaler = Autoscaler(self, policy, clock=clock)
        return self.autoscaler

    # ---- occupancy -----------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    def _alive(self) -> List[int]:
        return [i for i in range(len(self.engines))
                if self.health[i] != "dead"]

    def _eligible(self) -> List[int]:
        """Replicas that can accept/serve work now-or-soon: alive and not
        scaled down (warming counts — queued work waits out the cold
        start there)."""
        return [i for i in self._alive() if self.lifecycle[i] != "cold"]

    @property
    def capacity(self) -> int:
        """Total KV slots across replicas (replicas × slots when uniform)
        — what ``JAXExecutor`` derives its dispatch concurrency from.
        Dead replicas contribute nothing."""
        return sum(self.engines[i].slots for i in self._alive())

    @property
    def n_active(self) -> int:
        return sum(e.n_active for e in self.engines)

    @property
    def load(self) -> int:
        return sum(e.load for e in self.engines)

    @property
    def has_work(self) -> bool:
        return any(self.engines[i].has_work for i in self._alive())

    @property
    def all_saturated(self) -> bool:
        """True when no surviving replica has a free slot left (spill
        eligibility: cloud→edge spill must not fire while any live
        replica could still admit the request)."""
        return all(self.engines[i].load >= self.engines[i].slots
                   for i in self._alive())

    def occupancy(self) -> List[Dict[str, int]]:
        """Per-replica slot-lease snapshot."""
        return [{"replica": i, "slots": e.slots, "active": e.n_active,
                 "queued": len(e.queue),
                 "free": max(e.slots - e.load, 0),
                 "requests": e.stats["requests"],
                 "slot_reuses": e.stats["slot_reuses"],
                 "peak_active": e.stats["peak_active"],
                 "health": self.health[i],
                 "lifecycle": self.lifecycle[i]}
                for i, e in enumerate(self.engines)]

    # gauges describe one replica's high-water mark, not fleet volume:
    # summing them would report a concurrency that may never have existed
    _MAX_STATS = ("peak_active", "prefill_batch_max")

    @property
    def stats(self) -> Dict[str, object]:
        """Engine-shaped aggregate of every replica's counters: volumes
        sum, per-replica gauges take the max (per-replica values are in
        ``occupancy()``)."""
        agg: Dict[str, object] = {}
        for e in self.engines:
            for k, v in e.stats.items():
                if not isinstance(v, (int, float)):
                    if v is not None:
                        agg[k] = v
                elif k in self._MAX_STATS:
                    agg[k] = max(agg.get(k, 0), v)
                else:
                    agg[k] = agg.get(k, 0) + v
        agg.setdefault("prefill_backend", None)
        agg["replicas"] = self.n_replicas
        agg["pump_passes"] = self.pool_stats["pump_passes"]
        agg["deaths"] = self.pool_stats["deaths"]
        agg["failovers"] = self.pool_stats["failovers"]
        agg["suspects"] = self.pool_stats["suspects"]
        agg["hedges"] = self.pool_stats["hedges"]
        agg["replica_health"] = list(self.health)
        if self.autoscaler is not None:
            agg["replica_lifecycle"] = list(self.lifecycle)
            agg["autoscale"] = self.autoscaler.summary()
        return agg

    # ---- engine surface ------------------------------------------------
    def saturated(self) -> bool:
        """EngineLike surface: live occupancy says no replica can admit
        another request (spill eligibility; see ``all_saturated``)."""
        return self.all_saturated

    def submit(self, prompt, *, prefix_hint=None, **kw) -> Request:
        """Enqueue on the least-loaded surviving replica (healthy
        replicas beat suspect ones, warm replicas beat warming on equal
        load; then prefix affinity, then lowest index). An elastic pool
        with nothing warm is poked first — the first arrival after a gap
        starts a cold replica warming and queues on it.

        Prefix affinity: each replica keeps its own prefix index (it
        lives on the engine, so a dead replica's index dies with it and
        failed-over requests simply re-match on the survivor). Among
        equally loaded candidates the one holding the longest cached
        prefix of ``prefix_hint`` (the scheduler's DAG hint) — or of the
        prompt itself — wins, so co-scheduled siblings land where their
        shared context is already hot. Affinity never outranks load:
        reuse saves prefill, not decode, so piling onto a hot replica
        would trade a prefill skip for whole decode steps."""
        alive = self._alive()
        if not alive:
            raise RuntimeError("EnginePool.submit: all replicas are dead")
        if self.autoscaler is not None:
            self.autoscaler.tick()
            cands = self._eligible()
            if not cands:
                self.autoscaler.poke()
                cands = self._eligible()
            cands = cands or alive
        else:
            cands = alive
        match = {j: 0 for j in cands}
        if len(cands) > 1 and any(
                getattr(self.engines[j], "prefix_reuse", False)
                for j in cands):
            ids = prefix_hint
            if ids is None and isinstance(prompt, str):
                from repro.data import tokenizer as tok
                ids = tok.encode(prompt)
            elif ids is None:
                ids = list(prompt)
            for j in cands:
                fn = getattr(self.engines[j], "prefix_match_len", None)
                match[j] = fn(ids) if fn is not None else 0
        i = min(cands, key=lambda j: (self.health[j] != "healthy",
                                      self.engines[j].load,
                                      self.lifecycle[j] != "warm",
                                      -match[j], j))
        self.pool_stats["submitted"][i] += 1
        return self.engines[i].submit(prompt, **kw)

    # reprolint: hot
    def step(self) -> List[Request]:
        """One pool pass: step every surviving replica with pending work
        (see the module docstring for the threaded vs
        launch-all/commit-all pass shapes); for a single loaded replica
        this is exactly ``ServingEngine.step``. A replica that raises —
        from its worker thread, the fast path, or any sequential phase —
        is handed to ``_kill_replica`` *after* every sibling's results
        are joined, so one crash never loses another replica's finished
        requests or deadlocks the join. Only *warm* replicas step —
        warming replicas hold their queues until the autoscaler promotes
        them (every replica is warm when no autoscaler is armed)."""
        if self.autoscaler is not None:
            self.autoscaler.tick()
        loaded = [(i, self.engines[i]) for i in self._alive()
                  if self.engines[i].has_work
                  and self.lifecycle[i] == "warm"]
        if not loaded:
            return []
        self.pool_stats["pump_passes"] += 1
        finished: List[Request] = []
        errors: List[Tuple[int, BaseException]] = []
        if len(loaded) == 1:
            i, e = loaded[0]
            try:
                finished = e.step()
            except Exception as exc:
                errors.append((i, exc))
        elif self.threads:
            if self._tp is None:
                self._tp = ThreadPoolExecutor(
                    max_workers=len(self.engines),
                    thread_name_prefix="enginepool")
            # one worker per loaded replica: replica state is thread-
            # private, results join in replica-index order (determinism)
            futs = [(i, self._tp.submit(e.step)) for i, e in loaded]
            for i, f in futs:
                try:
                    finished.extend(f.result())
                except Exception as exc:
                    errors.append((i, exc))
        else:
            # launch-all/commit-all: a replica that raises in any phase
            # drops out of the later phases of this pass
            live = []
            for i, e in loaded:
                try:
                    e._admit()
                    live.append((i, e))
                except Exception as exc:
                    errors.append((i, exc))
            prefills = []
            for i, e in live:
                try:
                    prefills.append((i, e, e._prefill_launch()))
                except Exception as exc:
                    errors.append((i, exc))
            live = []
            for i, e, p in prefills:
                try:
                    if p is not None:
                        e._prefill_commit(p)
                    live.append((i, e))
                except Exception as exc:
                    errors.append((i, exc))
            decodes = []
            for i, e in live:
                try:
                    decodes.append((i, e, e._decode_launch()))
                except Exception as exc:
                    errors.append((i, exc))
            for i, e, d in decodes:
                try:
                    if d is not None:
                        finished.extend(e._decode_commit(d))
                except Exception as exc:
                    errors.append((i, exc))
        for i, exc in errors:
            self._kill_replica(i, exc)
        self._update_health()
        return finished

    # ---- failure handling ----------------------------------------------
    def _kill_replica(self, i: int, exc: BaseException) -> None:
        """Mark replica ``i`` dead and fail its work over to survivors
        (active slots in slot order, then queue FIFO — deterministic).
        Failed-over requests restart from the prompt: their generation
        state lived in the dead replica's KV slots. With
        ``failover=False`` the captured exception surfaces instead."""
        self.health[i] = "dead"
        self.pool_stats["replica_errors"].append(
            f"replica {i}: {type(exc).__name__}: {exc}")
        if not self.failover:
            raise RuntimeError(
                f"replica {i} step failed (failover disabled)") from exc
        self.pool_stats["deaths"] += 1
        dead = self.engines[i]
        orphans = [r for r in dead.active if r is not None and not r.done]
        orphans.extend(dead.queue)
        for r in orphans:
            dead.cancel(r)
        alive = self._alive()
        if orphans and not alive:
            raise RuntimeError(
                f"all {len(self.engines)} replicas dead with "
                f"{len(orphans)} requests stranded") from exc
        # failover lands on serving replicas; if the survivors are all
        # scaled down, poke one awake rather than stranding work cold
        targets = self._eligible()
        if orphans and not targets and self.autoscaler is not None:
            self.autoscaler.poke()
            targets = self._eligible()
        targets = targets or alive
        for r in orphans:
            j = min(targets, key=lambda j_: (self.health[j_] != "healthy",
                                             self.engines[j_].load,
                                             self.lifecycle[j_] != "warm",
                                             j_))
            r.output_ids.clear()
            r.done = False
            r._engine = self.engines[j]
            self.engines[j].queue.append(r)
            self.pool_stats["failovers"] += 1
            self.pool_stats["submitted"][j] += 1

    def _update_health(self) -> None:
        """Straggler detection (armed by ``suspect_after``): a replica
        holding work that makes no counter progress for N consecutive
        passes turns suspect and its work is hedged away; first progress
        afterwards restores it to healthy."""
        if self.suspect_after is None:
            return
        for i, e in enumerate(self.engines):
            if self.health[i] == "dead":
                continue
            prog = (e.stats["tokens_out"] + e.stats["prefill_tokens"]
                    + e.stats["requests"])
            if prog != self._last_progress[i]:
                self._last_progress[i] = prog
                self._stalled_passes[i] = 0
                if self.health[i] == "suspect":
                    self.health[i] = "healthy"
            elif e.has_work and self.lifecycle[i] == "warm":
                self._stalled_passes[i] += 1
                if (self._stalled_passes[i] >= self.suspect_after
                        and self.health[i] == "healthy"):
                    self.health[i] = "suspect"
                    self.pool_stats["suspects"] += 1
                    self._hedge_from(i)

    def _hedge_from(self, i: int) -> None:
        """Hedged re-dispatch: move a suspect replica's pending work onto
        strictly-healthy replicas (restarted from the prompt). The
        suspect keeps nothing but stays eligible to recover; with no
        healthy replica left the work stays put."""
        healthy = [j for j in range(len(self.engines))
                   if self.health[j] == "healthy"
                   and self.lifecycle[j] == "warm"]
        if not healthy:
            return
        src = self.engines[i]
        moved = [r for r in src.active if r is not None and not r.done]
        moved.extend(src.queue)
        for r in moved:
            src.cancel(r)
            j = min(healthy, key=lambda j_: (self.engines[j_].load, j_))
            r.output_ids.clear()
            r.done = False
            r._engine = self.engines[j]
            self.engines[j].queue.append(r)
            self.pool_stats["hedges"] += 1
            self.pool_stats["submitted"][j] += 1

    def cancel(self, req: Request) -> bool:
        """Cancel a pool-owned request wherever it currently lives."""
        owner = getattr(req, "_engine", None)
        for e in self.engines:
            if owner is e:
                return e.cancel(req)
        return False

    # reprolint: hot
    def pump(self) -> bool:
        """Advance every replica with pending work one step, in one
        pass. Returns whether anything progressed. Elastic pools tick
        their autoscaler even on empty passes — that is what lets a pool
        scale to zero during a traffic gap and promote warming replicas
        on wall-clock time."""
        if self.autoscaler is not None:
            self.autoscaler.tick()
        if not self.has_work:
            return False
        self.step()
        return True

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.has_work:
                break
            done.extend(self.step())
        return done

    def run_until(self, req: Request, max_steps: int = 10_000) -> Request:
        """Step the pool until ``req`` finishes; co-resident requests on
        every replica keep advancing on the same passes."""
        owner = getattr(req, "_engine", None)
        if not any(owner is e for e in self.engines):
            raise ValueError(
                f"request {req.rid} was never submitted to this pool "
                f"(submit() returns the Request object to wait on)")
        for _ in range(max_steps):
            if req.done:
                return req
            # re-resolve ownership every pass: failover/hedging may have
            # moved the request to another replica mid-wait
            owner = getattr(req, "_engine", None)
            if not any(owner is e for e in self.engines):
                raise RuntimeError(
                    f"request {req.rid} lost its replica mid-run "
                    f"(cancelled without failover?)")
            if not owner.has_work:
                raise RuntimeError(
                    f"replica drained with request {req.rid} unfinished "
                    f"(engine bug: an owned request left the queue)")
            self.step()
        if req.done:
            return req
        raise RuntimeError(f"request {req.rid} did not finish "
                           f"within {max_steps} pool passes")
