"""Replicated serving-engine pool: sharded cloud capacity with
replica-aware dispatch.

HybridFlow's cloud side was one ``ServingEngine`` with N slots, so
"cloud concurrency" was really a single replica's batch width. An
``EnginePool`` owns R engine replicas — **shared params** (one pytree,
no re-init) but **independent KV slot pools** — and exposes the same
``submit`` / ``has_work`` / ``step`` surface as a single engine, so
``JAXExecutor`` and the fleet scheduler drive either interchangeably.

Dispatch contract
-----------------
* **Least-loaded replica selection** — ``submit`` routes each request to
  the replica with the smallest *load* (active + queued requests); ties
  break to the lowest replica index. Selection is a pure function of the
  pool's current occupancy, so a given submit/step sequence is
  deterministic.
* **R = 1 identity** — a one-replica pool performs exactly the single
  engine's admit → prefill → decode sequence per ``step``; greedy tokens
  are bit-identical to driving the lone ``ServingEngine`` directly
  (tested through the live ``FleetScheduler``).
* **Pump pass** — ``pump()``/``step()`` advance *every* replica with
  pending work in one pass. With ``threads=True`` (default) each loaded
  replica's step runs on its own worker thread: jitted execution
  releases the GIL, so replica computes overlap on multi-core hosts the
  way they would on per-replica accelerators — two half-full replicas
  cost one step's wall-clock, not two. Replica state is strictly
  thread-private (each worker touches only its own engine) and finished
  requests are collected in replica-index order, so token streams and
  completion order stay deterministic. ``threads=False`` falls back to
  a sequential launch-all/commit-all pass: all replicas' prefill chunks
  are dispatched before any is synced, then all decode steps likewise,
  letting JAX's async dispatch overlap one replica's host-side commit
  with the next replica's device compute.
* **Saturation** — ``all_saturated`` is True only when every replica's
  load has reached its slot count. ``JAXExecutor.saturated()`` forwards
  it to the fleet scheduler, whose cloud→edge spill fires only then:
  a pool with any free replica slot keeps cloud-routed work on the
  cloud.
* **Occupancy stats** — ``occupancy()`` reports per-replica slot-lease
  state (active / queued / free / requests / slot_reuses / peak_active);
  ``stats`` aggregates the replicas' counters into one engine-shaped
  dict (plus ``replicas`` and ``pump_passes``) for drop-in reporting.

``EnginePool.replicate`` builds R fresh replicas from a config + params;
``EnginePool.like`` scales out an existing engine, keeping it as replica
0 (external handles to it stay live) and cloning R-1 siblings with
distinct sampling seeds.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Dict, List, Optional, Sequence

from repro.serving.engine import Request, ServingEngine


class EnginePool:
    """R serving-engine replicas behind one engine-shaped surface."""

    def __init__(self, engines: Sequence[ServingEngine], *,
                 threads: bool = True):
        if not engines:
            raise ValueError("EnginePool needs at least one replica")
        self.engines: List[ServingEngine] = list(engines)
        self.threads = threads
        self._tp: Optional[ThreadPoolExecutor] = None
        self.pool_stats: Dict[str, object] = {
            "pump_passes": 0,
            "submitted": [0] * len(self.engines),
        }

    # ---- constructors --------------------------------------------------
    @classmethod
    def replicate(cls, cfg, params, *, replicas: int, seed: int = 0,
                  threads: bool = True, **engine_kw) -> "EnginePool":
        """R fresh replicas sharing one params pytree. Replica i samples
        with ``seed + i`` so replica 0 matches a lone engine built with
        ``seed`` (the R=1 bit-identity guarantee)."""
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        return cls([ServingEngine(cfg, params, seed=seed + i, **engine_kw)
                    for i in range(replicas)], threads=threads)

    @classmethod
    def like(cls, engine: ServingEngine, replicas: int, *,
             threads: bool = True) -> "EnginePool":
        """Scale an existing engine out to R replicas: the given engine
        becomes replica 0 (its queue/slots are preserved), siblings are
        clones over the same params with distinct sampling seeds."""
        if replicas < 1:
            raise ValueError("replicas must be >= 1")
        return cls([engine] + [engine.clone(seed=engine.seed + i)
                               for i in range(1, replicas)],
                   threads=threads)

    # ---- occupancy -----------------------------------------------------
    @property
    def n_replicas(self) -> int:
        return len(self.engines)

    @property
    def capacity(self) -> int:
        """Total KV slots across replicas (replicas × slots when uniform)
        — what ``JAXExecutor`` derives its dispatch concurrency from."""
        return sum(e.slots for e in self.engines)

    @property
    def n_active(self) -> int:
        return sum(e.n_active for e in self.engines)

    @property
    def load(self) -> int:
        return sum(e.load for e in self.engines)

    @property
    def has_work(self) -> bool:
        return any(e.has_work for e in self.engines)

    @property
    def all_saturated(self) -> bool:
        """True when no replica has a free slot left (spill eligibility:
        cloud→edge spill must not fire while any replica could still
        admit the request)."""
        return all(e.load >= e.slots for e in self.engines)

    def occupancy(self) -> List[Dict[str, int]]:
        """Per-replica slot-lease snapshot."""
        return [{"replica": i, "slots": e.slots, "active": e.n_active,
                 "queued": len(e.queue),
                 "free": max(e.slots - e.load, 0),
                 "requests": e.stats["requests"],
                 "slot_reuses": e.stats["slot_reuses"],
                 "peak_active": e.stats["peak_active"]}
                for i, e in enumerate(self.engines)]

    # gauges describe one replica's high-water mark, not fleet volume:
    # summing them would report a concurrency that may never have existed
    _MAX_STATS = ("peak_active", "prefill_batch_max")

    @property
    def stats(self) -> Dict[str, object]:
        """Engine-shaped aggregate of every replica's counters: volumes
        sum, per-replica gauges take the max (per-replica values are in
        ``occupancy()``)."""
        agg: Dict[str, object] = {}
        for e in self.engines:
            for k, v in e.stats.items():
                if not isinstance(v, (int, float)):
                    if v is not None:
                        agg[k] = v
                elif k in self._MAX_STATS:
                    agg[k] = max(agg.get(k, 0), v)
                else:
                    agg[k] = agg.get(k, 0) + v
        agg.setdefault("prefill_backend", None)
        agg["replicas"] = self.n_replicas
        agg["pump_passes"] = self.pool_stats["pump_passes"]
        return agg

    # ---- engine surface ------------------------------------------------
    def submit(self, prompt, **kw) -> Request:
        """Enqueue on the least-loaded replica (ties → lowest index)."""
        i = min(range(len(self.engines)),
                key=lambda j: (self.engines[j].load, j))
        self.pool_stats["submitted"][i] += 1
        return self.engines[i].submit(prompt, **kw)

    def step(self) -> List[Request]:
        """One pool pass: step every replica with pending work (see the
        module docstring for the threaded vs launch-all/commit-all pass
        shapes); for a single loaded replica this is exactly
        ``ServingEngine.step``."""
        loaded = [e for e in self.engines if e.has_work]
        if not loaded:
            return []
        self.pool_stats["pump_passes"] += 1
        if len(loaded) == 1:
            return loaded[0].step()
        if self.threads:
            if self._tp is None:
                self._tp = ThreadPoolExecutor(
                    max_workers=len(self.engines),
                    thread_name_prefix="enginepool")
            # one worker per loaded replica: replica state is thread-
            # private, results join in replica-index order (determinism)
            futs = [self._tp.submit(e.step) for e in loaded]
            finished: List[Request] = []
            for f in futs:
                finished.extend(f.result())
            return finished
        for e in loaded:
            e._admit()
        prefills = [(e, e._prefill_launch()) for e in loaded]
        for e, p in prefills:
            if p is not None:
                e._prefill_commit(p)
        decodes = [(e, e._decode_launch()) for e in loaded]
        finished = []
        for e, d in decodes:
            if d is not None:
                finished.extend(e._decode_commit(d))
        return finished

    def pump(self) -> bool:
        """Advance every replica with pending work one step, in one
        pass. Returns whether anything progressed."""
        if not self.has_work:
            return False
        self.step()
        return True

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.has_work:
                break
            done.extend(self.step())
        return done

    def run_until(self, req: Request, max_steps: int = 10_000) -> Request:
        """Step the pool until ``req`` finishes; co-resident requests on
        every replica keep advancing on the same passes."""
        owner = getattr(req, "_engine", None)
        if not any(owner is e for e in self.engines):
            raise ValueError(
                f"request {req.rid} was never submitted to this pool "
                f"(submit() returns the Request object to wait on)")
        for _ in range(max_steps):
            if req.done:
                return req
            if not owner.has_work:
                raise RuntimeError(
                    f"replica drained with request {req.rid} unfinished "
                    f"(engine bug: an owned request left the queue)")
            self.step()
        if req.done:
            return req
        raise RuntimeError(f"request {req.rid} did not finish "
                           f"within {max_steps} pool passes")
