"""Deterministic fault injection for the serving stack (chaos harness).

HybridFlow treats the cloud as an expensive, *unreliable* resource — yet a
serving run is only trustworthy under failure if failures can be produced
on demand, identically, run after run. This module provides that harness:

* ``FaultPlan`` — a declarative, **seeded** description of what goes wrong:
  cloud submit failures, completion stalls, replica crashes at a given
  pump pass, persistently slow (straggler) replicas. Every decision is a
  pure function of ``(seed, kind, key, attempt)`` via a SHA-256 hash — no
  RNG state, so the same plan replays the same faults regardless of
  thread timing, replica count or poll order. ``FaultPlan.parse`` reads
  the compact spec string ``launch/serve.py --faults`` takes, e.g.
  ``"submit_fail=0.1,stall=0.05@0.3,crash=1@8,slow=0:4,seed=3"``.
* ``FaultInjector`` — the plan's runtime: owns per-(side, qid, sid)
  attempt counters (so a *retry* of the same subtask redraws its fault),
  an event log, and fault counters for reports.
* ``FaultyExecutor`` / ``FaultyAsyncExecutor`` — wrap any scheduler
  ``Executor`` (analytic or engine-backed). Submit faults raise
  ``InjectedFault`` from ``run``/``submit``; stalls inflate the simulated
  latency (sync) or hold a finished future past its completion (async),
  which is what arms the scheduler's deadline timeouts.
* ``FaultyReplica`` — wraps one ``EnginePool`` replica engine: crashes
  (raises from the pump step at pass N, once) and stragglers (the
  replica only does work every k-th pass) flow through the pool's
  health/failover machinery exactly like real replica failures.

The injector is *passive* by design: recovery lives in
``core.scheduler.RetryPolicy`` (retry / backoff / timeout / degrade) and
``serving.pool.EnginePool`` (health states + failover). A plan with all
rates at zero injects nothing and perturbs nothing — fault-free runs stay
bit-identical to an unwrapped stack (tested in ``tests/test_faults.py``).
"""
from __future__ import annotations

import hashlib
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class FaultError(RuntimeError):
    """A serving-side failure the scheduler may retry (base class for
    injected faults; real executor errors are handled the same way)."""


class InjectedFault(FaultError):
    """A failure produced by a ``FaultPlan`` (never a code bug)."""


def _unit(*parts) -> float:
    """Deterministic uniform [0, 1) from the key parts (no RNG state)."""
    h = hashlib.sha256("|".join(str(p) for p in parts).encode()).digest()
    return int.from_bytes(h[:8], "little") / 2.0 ** 64


@dataclass(frozen=True)
class FaultPlan:
    """Seeded chaos description; every field defaults to 'no fault'."""

    seed: int = 0
    submit_fail_rate: float = 0.0   # P(raise) per (qid, sid, attempt) submit
    stall_rate: float = 0.0         # P(stall) per (qid, sid, attempt)
    stall_s: float = 0.3            # stall duration: added latency (sim) /
    #                                 completion hold (async wall-clock)
    crash_replica: Tuple[Tuple[int, int], ...] = ()   # (replica, pump pass)
    slow_replica: Tuple[Tuple[int, int], ...] = ()    # (replica, every k-th)
    edge_faults: bool = False       # also inject on the edge executor

    @property
    def has_executor_faults(self) -> bool:
        return self.submit_fail_rate > 0 or self.stall_rate > 0

    @property
    def has_replica_faults(self) -> bool:
        return bool(self.crash_replica or self.slow_replica)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``--faults`` spec: comma-separated ``k=v`` items.

        ``seed=N`` | ``submit_fail=R`` | ``stall=R@SECS`` |
        ``crash=IDX@PASS`` | ``slow=IDX:K`` | ``edge=1`` — ``crash`` and
        ``slow`` may repeat (``crash=0@8,crash=1@20``).
        """
        kw: Dict = {"crash_replica": [], "slow_replica": []}
        for item in filter(None, (s.strip() for s in spec.split(","))):
            key, _, val = item.partition("=")
            if key == "seed":
                kw["seed"] = int(val)
            elif key in ("submit_fail", "fail"):
                kw["submit_fail_rate"] = float(val)
            elif key == "stall":
                rate, _, secs = val.partition("@")
                kw["stall_rate"] = float(rate)
                if secs:
                    kw["stall_s"] = float(secs)
            elif key == "crash":
                idx, _, at = val.partition("@")
                kw["crash_replica"].append((int(idx), int(at or 1)))
            elif key == "slow":
                idx, _, k = val.partition(":")
                kw["slow_replica"].append((int(idx), int(k or 2)))
            elif key == "edge":
                kw["edge_faults"] = val not in ("0", "false", "")
            else:
                raise ValueError(f"unknown --faults item {item!r}")
        kw["crash_replica"] = tuple(kw["crash_replica"])
        kw["slow_replica"] = tuple(kw["slow_replica"])
        return cls(**kw)


class FaultInjector:
    """Runtime of one ``FaultPlan``: counters, event log and wrappers."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.stats = {"submit_faults": 0, "stalls": 0, "replica_crashes": 0,
                      "replica_skips": 0}
        self.events: List[Tuple] = []
        self._attempts: Dict[Tuple, int] = {}
        self._crashed: set = set()

    # ---- executor-side decisions ---------------------------------------
    def on_submit(self, side: str, qid: str, sid: int) -> int:
        """Draw the submit fault for this attempt; raises ``InjectedFault``
        on a hit. Returns the attempt index consumed (0-based) so the
        stall draw for the same attempt stays aligned."""
        key = (side, qid, sid)
        attempt = self._attempts.get(key, 0)
        self._attempts[key] = attempt + 1
        if (self.plan.submit_fail_rate > 0
                and _unit(self.plan.seed, "submit", side, qid, sid, attempt)
                < self.plan.submit_fail_rate):
            self.stats["submit_faults"] += 1
            self.events.append(("submit_fault", side, qid, sid, attempt))
            raise InjectedFault(
                f"injected {side} submit failure (qid={qid}, sid={sid}, "
                f"attempt={attempt})")
        return attempt

    def stall_for(self, side: str, qid: str, sid: int, attempt: int) -> float:
        """Stall duration (seconds) for this attempt; 0.0 = no stall."""
        if (self.plan.stall_rate > 0
                and _unit(self.plan.seed, "stall", side, qid, sid, attempt)
                < self.plan.stall_rate):
            self.stats["stalls"] += 1
            self.events.append(("stall", side, qid, sid, attempt))
            return self.plan.stall_s
        return 0.0

    # ---- replica-side decisions ----------------------------------------
    def replica_tick(self, idx: int, pump_pass: int) -> None:
        """Raises ``InjectedFault`` when replica ``idx`` is due to crash
        (once; the pool marks it dead and fails its work over)."""
        for ridx, at in self.plan.crash_replica:
            if ridx == idx and pump_pass >= at and idx not in self._crashed:
                self._crashed.add(idx)
                self.stats["replica_crashes"] += 1
                self.events.append(("replica_crash", idx, pump_pass))
                raise InjectedFault(
                    f"injected crash of replica {idx} at pump pass "
                    f"{pump_pass}")

    def replica_skips(self, idx: int, pump_pass: int) -> bool:
        """True when straggler replica ``idx`` sits out this pass (it only
        makes progress every k-th pass)."""
        for ridx, k in self.plan.slow_replica:
            if ridx == idx and k > 1 and pump_pass % k != 0:
                self.stats["replica_skips"] += 1
                return True
        return False

    # ---- wrappers -------------------------------------------------------
    def wrap_executor(self, ex, side: Optional[str] = None):
        """Wrap a scheduler Executor (async surface detected)."""
        side = side or ("cloud" if getattr(ex, "cloud", True) else "edge")
        cls = FaultyAsyncExecutor if hasattr(ex, "submit") else FaultyExecutor
        return cls(ex, self, side)

    def wrap_pool(self, pool):
        """Wrap every replica of an ``EnginePool`` in place (crash/slow
        injection); returns the pool."""
        pool.engines = [FaultyReplica(e, self, i)
                        for i, e in enumerate(pool.engines)]
        return pool


class FaultyExecutor:
    """Synchronous Executor wrapper: injects on ``run`` (sim driver)."""

    def __init__(self, inner, injector: FaultInjector, side: str):
        self._inner = inner
        self._injector = injector
        self._side = side

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def run(self, query, node, dep_results):
        attempt = self._injector.on_submit(self._side, query.qid, node.sid)
        res = self._inner.run(query, node, dep_results)
        extra = self._injector.stall_for(self._side, query.qid, node.sid,
                                         attempt)
        if extra:
            res.latency += extra     # the sim clock sees the stall
        return res


class FaultyAsyncExecutor(FaultyExecutor):
    """Async Executor wrapper: submit faults raise, stalls hold a finished
    future for ``stall_s`` wall-clock seconds past its true completion —
    the scheduler's deadline timeout is what rescues a held subtask."""

    def __init__(self, inner, injector: FaultInjector, side: str):
        super().__init__(inner, injector, side)
        self._holds: Dict[int, List[Optional[float]]] = {}

    def submit(self, query, node, dep_results, **kw):
        # **kw passes scheduler extras (e.g. prefix_hint) through to the
        # wrapped executor — chaos must not strip the KV affinity signal
        attempt = self._injector.on_submit(self._side, query.qid, node.sid)
        h = self._inner.submit(query, node, dep_results, **kw)
        extra = self._injector.stall_for(self._side, query.qid, node.sid,
                                         attempt)
        if extra:
            self._holds[id(h)] = [extra, None]   # [hold_s, release_at]
        return h

    def poll(self, h):
        res = self._inner.poll(h)
        if res is None:
            return None
        hold = self._holds.get(id(h))
        if hold is not None:
            if hold[1] is None:                  # first sighting of done
                hold[1] = time.perf_counter() + hold[0]
            if time.perf_counter() < hold[1]:
                return None
            del self._holds[id(h)]
        return res

    def cancel(self, h) -> bool:
        self._holds.pop(id(h), None)
        cancel = getattr(self._inner, "cancel", None)
        return bool(cancel(h)) if cancel is not None else False


@dataclass
class FaultyReplica:
    """One ``EnginePool`` replica under chaos: counts its own pump passes
    and consults the injector — a due crash raises out of the pass (the
    pool's failover path takes over), a straggler pass does no work while
    ``has_work`` stays true (the pool's suspect/hedge path takes over).
    Everything else delegates to the wrapped ``ServingEngine``."""

    _inner: object
    _injector: FaultInjector
    _idx: int
    _pass: int = 0
    _skip: bool = field(default=False, repr=False)

    def __getattr__(self, name):
        return getattr(self._inner, name)

    def _tick(self) -> None:
        self._pass += 1
        self._injector.replica_tick(self._idx, self._pass)
        self._skip = self._injector.replica_skips(self._idx, self._pass)

    def submit(self, prompt, **kw):
        req = self._inner.submit(prompt, **kw)
        req._engine = self           # ownership points at the wrapper so
        return req                   # pool cancel/run_until resolve to it

    # one pump pass enters either through step() (threaded / single-loaded
    # pool pass) or through _admit() (sequential launch-all/commit-all
    # pass); both tick exactly once per pass
    def step(self):
        self._tick()
        if self._skip:
            return []
        return self._inner.step()

    def _admit(self):
        self._tick()
        if not self._skip:
            self._inner._admit()

    def _prefill_launch(self):
        return None if self._skip else self._inner._prefill_launch()

    def _decode_launch(self):
        return None if self._skip else self._inner._decode_launch()


__all__ = ["FaultError", "InjectedFault", "FaultPlan", "FaultInjector",
           "FaultyExecutor", "FaultyAsyncExecutor", "FaultyReplica"]
