"""Multi-query serving runtime: the fleet-level front-end to HybridFlow.

``ServingRuntime`` admits many ``Query`` objects at once, plans each one
(unless a pre-planned DAG is supplied), and drains them through the shared
``FleetScheduler`` event loop: ready subtasks from *all* in-flight queries
multiplex onto one edge pool and one cloud pool with round-robin fairness,
bounded admission (``max_inflight``), optional fleet-wide budget caps and
optional cloud→edge spill under saturation. Per-query budgets stay where
the paper puts them — inside the routing policy's ``TwoBudgetThreshold``
duals — while the runtime adds the *global* dual the single-query code
had no place for.

Quickstart (analytic world-model executors)::

    from repro.core.hybridflow import Pipeline, HybridFlowPolicy
    from repro.core.profiler import train_default_router
    from repro.data.tasks import gen_benchmark
    from repro.serving.runtime import ServingConfig, ServingRuntime

    pipe = Pipeline()                      # edge + cloud executor pair
    router, _ = train_default_router()
    policy = HybridFlowPolicy(router, wm=pipe.wm)
    rt = ServingRuntime(pipe.edge, pipe.cloud, policy,
                        planner=pipe.planner,
                        config=ServingConfig(max_inflight=8,
                                             global_k_max=1.0))
    report = rt.serve(gen_benchmark("gpqa", 32))
    print(report.qps, report.p50_latency, report.p99_latency)

All runtime knobs live on the frozen :class:`ServingConfig`; the PR 8
flat-kwargs deprecation shim is gone (its one-release window is up), so
``ServingRuntime(edge, cloud, policy, planner=, config=)`` is the whole
constructor surface and anything else raises ``TypeError``. One
dispatcher runs every mode::

    rt.serve(queries)                          # closed loop (fleet)
    rt.serve(queries, mode="sequential")       # one-at-a-time baseline
    rt.serve(queries, arrivals=trace)          # open loop (timed admission)
    rt.serve_trace(trace, queries)             # alias for the above

Open-loop serving replays a ``serving.traffic.Trace`` (seeded Poisson /
day-cycle / burst arrival schedules): queries enter the fleet at their
arrival times, per-query TTFT and queue wait land on each
``QueryResult``, and ``report.trace`` carries offered-vs-served RPS plus
any autoscaler decisions. An elastic cloud
(``ServingConfig(replicas=R, autoscale=AutoscalePolicy(...))``) grows
and shrinks warm replicas from live occupancy, pays a modeled cold
start, scales to zero in traffic gaps and re-warms on the first arrival
after one (see ``serving.pool``).

The same runtime drives real JAX engines by passing ``JAXExecutor`` pairs
(see ``examples/serve_hybrid.py``). Async executors are auto-detected and
drained through the fleet scheduler's *pump loop*: every dispatch is a
``submit`` into the executor's serving engine, the loop keeps stepping
each engine while routing continues, and co-scheduled subtasks from
different queries decode in the same micro-batches — wall-clock then
tracks the simulated makespan instead of serializing subtask-by-subtask.
``pump=False`` forces the pre-pump synchronous dispatch (the perf
baseline in ``benchmarks/serve_throughput.py``); latency is measured
wall-clock from actual batched decode steps either way. ``replicas=R``
shards an engine-backed cloud executor across an R-replica
``EnginePool`` (shared params, independent KV slot pools, least-loaded
dispatch): cloud concurrency then derives from pool capacity and the
report's stats carry per-replica occupancy.

Fault tolerance: ``retry=RetryPolicy(...)`` arms scheduler-side recovery
(retry w/ backoff, deadline timeouts, cloud→edge degradation — see
``core.scheduler``), and ``faults=`` injects deterministic chaos (a
``FaultPlan``, a pre-built ``FaultInjector``, or a spec string like
``"submit_fail=0.1,crash=1@8,seed=3"`` — see ``serving.faults``): the
cloud executor (and with ``edge=1`` the edge too) is wrapped for
submit-failure/stall injection and an ``EnginePool``-backed cloud gets
its replicas wrapped for crash/straggler injection. Passing ``faults``
without ``retry`` defaults to ``RetryPolicy()`` — injecting failures
with recovery disarmed would only prove the fleet can crash. Fault and
recovery counters land in ``report.stats`` (``injected``, ``retries``,
``timeouts``, ``degraded``, ``cloud_deaths``/``cloud_failovers``…).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.dag import PlanDAG
from repro.core.dual import TwoBudgetThreshold
from repro.core.scheduler import (Executor, FleetScheduler, QueryResult,
                                  RetryPolicy, RoutingPolicy, Schedule)
from repro.data.tasks import Query
from repro.serving.traffic import Trace


@dataclass(frozen=True)
class ServingConfig:
    """Every ``ServingRuntime`` knob in one frozen value object.

    Admission & budgets:
      * ``max_inflight`` — concurrently admitted queries (None = no cap)
      * ``global_k_max`` / ``global_l_max`` — fleet-wide $ / wall-clock
        budget caps (see ``_global_threshold``)
      * ``spill_to_edge`` — re-route cloud-bound work to an idle edge
        slot when the cloud is saturated

    Drivers & capacity:
      * ``pump`` — event-loop driver: True = real-time pump loop,
        False = synchronous dispatch, None = auto-detect from executors
      * ``replicas`` — shard an engine-backed cloud executor across an
        R-replica ``EnginePool``
      * ``autoscale`` — an ``AutoscalePolicy`` making that pool elastic
        (requires a pooled, engine-backed cloud)

    Fault tolerance:
      * ``retry`` — scheduler-side recovery (``RetryPolicy``)
      * ``faults`` — deterministic chaos: a ``FaultPlan``, a built
        ``FaultInjector`` or a spec string ("submit_fail=0.1,...")
      * ``stall_grace`` — idle seconds the pumped driver tolerates
        before declaring the fleet stalled (recovery armed only)
    """

    max_inflight: Optional[int] = 8
    global_k_max: Optional[float] = None
    global_l_max: Optional[float] = None
    spill_to_edge: bool = False
    pump: Optional[bool] = None
    replicas: Optional[int] = None
    autoscale: Optional["AutoscalePolicy"] = None  # noqa: F821 (lazy import)
    retry: Optional[RetryPolicy] = None
    faults: object = None
    stall_grace: float = 5.0


@dataclass
class RuntimeReport:
    """Fleet-level outcome of one ``serve`` call (any mode)."""

    results: List[QueryResult]
    makespan: float            # simulated fleet makespan (s)
    wall_s: float              # real wall-clock spent inside the loop
    stats: Dict[str, int] = field(default_factory=dict)
    # open-loop only: offered traffic + autoscale outcome (None otherwise,
    # keeping the closed-loop report shape exactly as before)
    trace: Optional[Dict[str, object]] = None

    @property
    def n(self) -> int:
        return len(self.results)

    @property
    def qps(self) -> float:
        """Queries per simulated second (fleet throughput)."""
        return self.n / self.makespan if self.makespan > 0 else 0.0

    @property
    def accuracy(self) -> float:
        if not self.results:
            return 0.0
        return float(np.mean([r.final_correct for r in self.results]))

    @property
    def api_cost(self) -> float:
        return float(sum(r.api_cost for r in self.results))

    def latency_percentile(self, p: float) -> float:
        """Percentile of per-query makespans (admission -> finish)."""
        if not self.results:
            return 0.0
        return float(np.percentile([r.latency for r in self.results], p))

    @property
    def p50_latency(self) -> float:
        return self.latency_percentile(50)

    @property
    def p99_latency(self) -> float:
        return self.latency_percentile(99)

    def ttft_percentile(self, p: float) -> float:
        """Percentile of per-query TTFT (arrival -> first completed
        subtask); meaningful for open-loop runs."""
        if not self.results:
            return 0.0
        return float(np.percentile([r.ttft for r in self.results], p))

    @property
    def p50_ttft(self) -> float:
        return self.ttft_percentile(50)

    @property
    def p99_ttft(self) -> float:
        return self.ttft_percentile(99)

    def queue_wait_percentile(self, p: float) -> float:
        """Percentile of per-query admission wait (arrival -> admission)."""
        if not self.results:
            return 0.0
        return float(np.percentile([r.queue_wait for r in self.results], p))

    def summary(self) -> str:
        line = (f"{self.n} queries | makespan {self.makespan:.2f}s | "
                f"{self.qps:.2f} q/s | acc {self.accuracy:.2f} | "
                f"p50 {self.p50_latency:.2f}s p99 {self.p99_latency:.2f}s | "
                f"API ${self.api_cost:.4f}")
        if self.trace is not None:
            line += (f" | offered {self.trace['offered_rps']:.2f} rps | "
                     f"ttft p50 {self.p50_ttft:.2f}s "
                     f"p99 {self.p99_ttft:.2f}s | queue p99 "
                     f"{self.queue_wait_percentile(99):.2f}s")
        return line


def _global_threshold(k_max: Optional[float],
                      l_max: Optional[float]) -> Optional[TwoBudgetThreshold]:
    """Fleet-wide dual: tau hits 1.0 when k_used/k_max + l_used/l_max
    reaches 1 — with one cap set that is exactly that budget's exhaustion;
    with both set the *sum* of fractional spends is capped (a linear
    combined budget, conservative by construction). That is the point
    where FleetScheduler starts forcing edge routing."""
    if k_max is None and l_max is None:
        return None
    k = math.inf if k_max is None else max(k_max, 0.0) / 2.0
    l = math.inf if l_max is None else max(l_max, 0.0) / 2.0
    # a zero cap means "no cloud budget at all": exhausted from the start
    tau0 = 1.0 if (k == 0.0 or l == 0.0) else 0.0
    return TwoBudgetThreshold(tau0=tau0, k_max=k or math.inf,
                              l_max=l or math.inf)


class ServingRuntime:
    """Admit -> plan -> fleet-execute many queries over shared pools."""

    def __init__(self, edge: Executor, cloud: Executor,
                 policy: RoutingPolicy, *, planner=None,
                 config: Optional[ServingConfig] = None):
        # the PR 8 flat-kwargs deprecation shim served its one-release
        # window and is gone: every runtime knob lives on ServingConfig,
        # and an unknown kwarg is a plain TypeError from Python itself
        cfg = config if config is not None else ServingConfig()
        self.config = cfg
        self.edge = edge
        self.cloud = self._pooled_cloud(cloud, cfg.replicas)
        self._arm_autoscale(cfg.autoscale)
        self.policy = policy
        self.planner = planner
        self.max_inflight = cfg.max_inflight
        self.global_k_max = cfg.global_k_max
        self.global_l_max = cfg.global_l_max
        self.spill_to_edge = cfg.spill_to_edge
        self.pump = cfg.pump
        self.stall_grace = cfg.stall_grace
        self.fault_injector = self._make_injector(cfg.faults)
        # chaos without recovery would only prove the fleet can crash
        self.retry = cfg.retry \
            if cfg.retry is not None or cfg.faults is None \
            else RetryPolicy()
        self._wrap_faulty()
        self.global_budget: Optional[TwoBudgetThreshold] = None
        self._pending: List[Tuple[Query, PlanDAG, str,
                                  Optional[Schedule]]] = []

    def _arm_autoscale(self, policy) -> None:
        """Make the (pooled, engine-backed) cloud elastic."""
        if policy is None:
            return
        from repro.serving.pool import EnginePool
        eng = getattr(self.cloud, "engine", None)
        if not isinstance(eng, EnginePool):
            raise ValueError(
                "autoscale= needs an EnginePool-backed cloud executor — "
                "pass ServingConfig(replicas=R, autoscale=...) or build "
                "the JAXExecutor over an EnginePool yourself")
        eng.arm_autoscale(policy)

    @staticmethod
    def _make_injector(faults):
        """Accept a spec string, a FaultPlan, or a ready FaultInjector."""
        if faults is None:
            return None
        from repro.serving.faults import FaultInjector, FaultPlan
        if isinstance(faults, FaultInjector):
            return faults
        if isinstance(faults, str):
            faults = FaultPlan.parse(faults)
        return FaultInjector(faults)

    def _wrap_faulty(self) -> None:
        """Install the fault plan: wrap executors for submit/stall
        injection and pool replicas for crash/straggler injection."""
        inj = self.fault_injector
        if inj is None:
            return
        plan = inj.plan
        if plan.has_replica_faults:
            from repro.serving.pool import EnginePool
            eng = getattr(self.cloud, "engine", None)
            if not isinstance(eng, EnginePool):
                raise ValueError(
                    "replica faults (crash=/slow=) need an EnginePool-"
                    "backed cloud executor (pass replicas=R)")
            inj.wrap_pool(eng)
        if plan.has_executor_faults:
            self.cloud = inj.wrap_executor(self.cloud, side="cloud")
            if plan.edge_faults:
                self.edge = inj.wrap_executor(self.edge, side="edge")

    @staticmethod
    def _pooled_cloud(cloud: Executor, replicas: Optional[int]) -> Executor:
        """Thread ``replicas=`` through to the cloud side: scale an
        engine-backed cloud executor out to an R-replica ``EnginePool``
        (shared params, independent KV slot pools). Dispatch concurrency
        then derives from pool capacity (replicas × slots) — unless the
        caller set an explicit cap on the executor, which is an admission
        policy and survives pooling unchanged — and cloud→edge spill
        fires only when every replica is saturated. ``None`` leaves the
        executor untouched (including pre-built pools)."""
        if replicas is None:
            return cloud
        if replicas < 1:
            raise ValueError("replicas must be >= 1 (or None)")
        eng = getattr(cloud, "engine", None)
        if eng is None:
            raise ValueError(
                "replicas= needs an engine-backed cloud executor "
                "(JAXExecutor); analytic executors model cloud width "
                "through their concurrency directly")
        from repro.serving.engine import JAXExecutor
        from repro.serving.pool import EnginePool
        if isinstance(eng, EnginePool):
            if eng.n_replicas != replicas:
                raise ValueError(
                    f"cloud executor already holds a {eng.n_replicas}-"
                    f"replica pool; cannot rescale to replicas={replicas}")
            return cloud
        pool = EnginePool.like(eng, replicas)
        keep_cap = None if getattr(cloud, "derived_concurrency", True) \
            else cloud.concurrency
        return JAXExecutor(pool, cloud.wm, cloud=True,
                           concurrency=keep_cap,
                           price_out=cloud.price_out)

    def _pool_occupancy(self, stats: Dict) -> Dict:
        """Attach per-replica slot-lease stats for engine-backed pools,
        plus KV prefix-reuse counters for any engine-backed side.

        Runs after the fleet loop returns, so no pool worker is live:
        reading engine ``stats`` (replica-private under the
        thread-ownership contract — see ``serving/__init__`` and
        tools/reprolint/README.md) is safe here without any barrier."""
        for name, ex in (("edge", self.edge), ("cloud", self.cloud)):
            eng = getattr(ex, "engine", None)
            est = getattr(eng, "stats", None)
            if est is not None and "prefix_hits" in est:
                stats[f"{name}_prefix_hits"] = est["prefix_hits"]
                stats[f"{name}_prefill_tokens_saved"] = \
                    est["prefill_tokens_saved"]
            occ = getattr(eng, "occupancy", None)
            if occ is None:
                continue
            stats[f"{name}_replicas"] = eng.n_replicas
            stats[f"{name}_replica_requests"] = [o["requests"]
                                                 for o in occ()]
            stats[f"{name}_pump_passes"] = eng.pool_stats["pump_passes"]
            for key in ("deaths", "failovers", "suspects", "hedges"):
                if key in eng.pool_stats:
                    stats[f"{name}_{key}"] = eng.pool_stats[key]
            health = getattr(eng, "health", None)
            if health is not None:
                stats[f"{name}_replica_health"] = list(health)
            scaler = getattr(eng, "autoscaler", None)
            if scaler is not None:
                stats[f"{name}_lifecycle"] = list(eng.lifecycle)
                stats[f"{name}_autoscale"] = scaler.summary()
        if self.fault_injector is not None:
            stats["injected"] = dict(self.fault_injector.stats)
        return stats

    # ---- admission ----------------------------------------------------
    def submit(self, query: Query, dag: Optional[PlanDAG] = None, *,
               plan_status: str = "valid",
               schedule_out: Optional[Schedule] = None) -> int:
        """Enqueue one query; plans it if no DAG is supplied."""
        if dag is None:
            if self.planner is None:
                raise ValueError("no DAG given and no planner configured")
            dag, plan_status = self.planner.plan(query)
        self._pending.append((query, dag, plan_status, schedule_out))
        return len(self._pending) - 1

    # ---- execution ----------------------------------------------------
    def serve(self, queries: Sequence[Query] = (), *,
              arrivals: Union[Trace, Sequence[float], None] = None,
              mode: str = "fleet") -> RuntimeReport:
        """One dispatcher for every serving mode.

        * ``mode="fleet"`` (default), no ``arrivals`` — closed loop:
          drain everything submitted (plus ``queries``) concurrently.
        * ``mode="fleet"``, ``arrivals=`` a ``Trace`` or a sequence of
          arrival times (seconds, one per query in submit order) — open
          loop: queries enter the fleet at their arrival times and the
          report carries TTFT / queue-wait / offered-RPS metrics.
        * ``mode="sequential"`` — the one-query-at-a-time baseline
          (delegates to ``serve_sequential``; no arrivals).
        """
        if mode == "sequential":
            if arrivals is not None:
                raise ValueError("arrivals= requires mode='fleet'")
            return self.serve_sequential(queries)
        if mode != "fleet":
            raise ValueError(f"unknown serve mode {mode!r} "
                             f"(expected 'fleet' or 'sequential')")
        for q in queries:
            self.submit(q)
        batch, self._pending = self._pending, []
        times: Optional[List[float]] = None
        if arrivals is not None:
            times = [float(a) for a in arrivals]
            if len(times) != len(batch):
                raise ValueError(
                    f"arrivals length {len(times)} != {len(batch)} "
                    f"queries (one arrival time per query, submit order)")
        self.global_budget = _global_threshold(self.global_k_max,
                                               self.global_l_max)
        fleet = FleetScheduler(self.edge, self.cloud,
                               max_inflight=self.max_inflight,
                               global_budget=self.global_budget,
                               spill_to_edge=self.spill_to_edge,
                               pump=self.pump, retry=self.retry,
                               stall_grace=self.stall_grace)
        for i, (q, dag, status, sched) in enumerate(batch):
            fleet.submit(q, dag, self.policy, plan_status=status,
                         schedule_out=sched,
                         arrival=times[i] if times else 0.0)
        t0 = time.perf_counter()
        results = fleet.run()
        wall = time.perf_counter() - t0
        report = RuntimeReport(
            results, fleet.makespan, wall,
            stats=self._pool_occupancy(dict(fleet.stats)))
        if times is not None:
            report.trace = self._trace_summary(arrivals, times, report)
        return report

    def serve_trace(self, trace: Trace,
                    queries: Sequence[Query] = ()) -> RuntimeReport:
        """Replay an open-loop arrival trace: ``len(trace)`` queries
        (submitted + ``queries``) enter the fleet at the trace's arrival
        times. Alias for ``serve(queries, arrivals=trace)``."""
        return self.serve(queries, arrivals=trace)

    def _trace_summary(self, arrivals, times: List[float],
                       report: RuntimeReport) -> Dict[str, object]:
        """Offered-vs-served traffic summary attached to the report."""
        horizon = arrivals.duration if isinstance(arrivals, Trace) \
            else (max(times) if times else 0.0)
        out: Dict[str, object] = {
            "n": len(times),
            "duration": float(horizon),
            "offered_rps": len(times) / horizon if horizon > 0 else 0.0,
            "served_rps": report.qps,
        }
        if isinstance(arrivals, Trace):
            out["label"] = arrivals.label
            out["seed"] = arrivals.seed
            out["target_rps"] = arrivals.target_rps
        scaler = getattr(getattr(self.cloud, "engine", None),
                         "autoscaler", None)
        if scaler is not None:
            out["autoscale"] = scaler.summary()
        return out

    def serve_sequential(self, queries: Sequence[Query] = ()) -> RuntimeReport:
        """One-query-at-a-time baseline (the seed's serving shape): each
        query runs alone on the pools; fleet makespan is the plain sum."""
        for q in queries:
            self.submit(q)
        batch, self._pending = self._pending, []
        self.global_budget = _global_threshold(self.global_k_max,
                                               self.global_l_max)
        results: List[QueryResult] = []
        stats: Dict[str, int] = {}
        makespan = 0.0
        t0 = time.perf_counter()
        for q, dag, status, sched in batch:
            fleet = FleetScheduler(self.edge, self.cloud,
                                   global_budget=self.global_budget,
                                   pump=self.pump, retry=self.retry,
                                   stall_grace=self.stall_grace)
            fleet.submit(q, dag, self.policy, plan_status=status,
                         schedule_out=sched)
            results.extend(fleet.run())
            makespan += fleet.makespan
            for k, v in fleet.stats.items():
                stats[k] = stats.get(k, 0) + v
        wall = time.perf_counter() - t0
        stats["peak_inflight"] = 1 if batch else 0
        return RuntimeReport(results, makespan, wall,
                             stats=self._pool_occupancy(stats))
