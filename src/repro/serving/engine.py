"""Serving engine: batched prefill + decode over any repro model.

The engine serves fixed-size micro-batches with a KV cache pool:
``submit`` enqueues requests, ``step`` admits waiting requests into free
slots (continuous batching), prefills them, and advances every active
request by one decode token. Greedy or temperature sampling.

``JAXExecutor`` adapts an engine pair to HybridFlow's Executor protocol so
the paper's scheduler can drive *real* JAX models (examples/serve_hybrid).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.data import tokenizer as tok
from repro.models import model as M
from repro.models import kvcache as KV


@dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled by the engine:
    output_ids: List[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def text(self) -> str:
        return tok.decode(self.output_ids)


class ServingEngine:
    """Slot-based continuous batching engine for one model."""

    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, dtype=jnp.float32, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.dtype = dtype
        self.key = jax.random.PRNGKey(seed)
        self.cache = M.init_cache(cfg, batch_slots, max_len, dtype=dtype)
        self.pos = np.zeros(batch_slots, np.int64)        # next position
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._rid = 0
        self._slot_used = [False] * batch_slots
        self._decode = jax.jit(
            lambda p, t, pos, c: M.serve_decode(p, cfg, t, pos, c))
        self.stats = {"tokens_out": 0, "prefill_tokens": 0, "steps": 0,
                      "slot_reuses": 0, "peak_active": 0, "requests": 0}

    # ---- public API ---------------------------------------------------
    def submit(self, prompt: str | List[int], *, max_new_tokens: int = 32,
               temperature: float = 0.0) -> Request:
        ids = tok.encode(prompt) if isinstance(prompt, str) else list(prompt)
        ids = [min(i, self.cfg.vocab_size - 1) for i in ids]
        req = Request(self._rid, ids, max_new_tokens, temperature,
                      submitted_at=time.time())
        self._rid += 1
        self.queue.append(req)
        return req

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.queue and all(a is None for a in self.active):
                break
            done.extend(self.step())
        return done

    def run_until(self, req: Request, max_steps: int = 10_000) -> Request:
        """Step the engine until ``req`` finishes (continuous batching:
        co-resident requests from other queries advance on the same decode
        steps — the fleet runtime's slot-sharing entry point)."""
        for _ in range(max_steps):
            if req.done:
                return req
            if not self.queue and all(a is None for a in self.active):
                break  # req never entered the engine
            self.step()
        if not req.done:
            raise RuntimeError(f"request {req.rid} did not finish "
                               f"within {max_steps} engine steps")
        return req

    @property
    def n_active(self) -> int:
        return sum(a is not None for a in self.active)

    # ---- engine internals ----------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.pop(0)
                self.active[slot] = req
                # slot lease accounting: KV lines are a fixed pool; a
                # reused slot means the cache allocation was recycled
                # rather than grown (the bounded-pool invariant)
                if self._slot_used[slot]:
                    self.stats["slot_reuses"] += 1
                self._slot_used[slot] = True
                self.stats["requests"] += 1
                self._prefill_slot(slot, req)
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        self.n_active)

    def _prefill_slot(self, slot: int, req: Request) -> None:
        """Single-request prefill into this slot of the shared cache.

        Uses a batch-1 prefill then writes the slot's cache lines — simple
        and correct; a production engine would batch prefills too.
        """
        ids = req.prompt_ids[-(self.max_len - req.max_new_tokens - 1):]
        batch = {"tokens": jnp.asarray([ids], jnp.int32)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.n_image_patches, self.cfg.d_model), self.dtype)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), self.dtype)
        cache1 = M.init_cache(self.cfg, 1, self.max_len, dtype=self.dtype)
        logits, cache1 = M.serve_prefill(self.params, self.cfg, batch, cache1)
        # copy slot lines: every cache leaf has batch at axis -? => leaves
        # follow [L, B, ...] or [B, ...]; match by dim size
        def write(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == self.slots and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            if dst.shape[0] == self.slots and src.shape[0] == 1:
                return dst.at[slot].set(src[0])
            # nested stacks ([G, m, B, ...]): search batch axis
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.slots and src.shape[ax] == 1:
                    idx = tuple([slice(None)] * ax + [slot])
                    sidx = tuple([slice(None)] * ax + [0])
                    return dst.at[idx].set(src[sidx])
            raise ValueError(f"no batch axis: {dst.shape} <- {src.shape}")

        self.cache = jax.tree.map(write, self.cache, cache1)
        n_img = self.cfg.n_image_patches if self.cfg.family == "vlm" else 0
        self.pos[slot] = len(ids) + n_img
        self.stats["prefill_tokens"] += len(ids)
        req.output_ids.append(self._sample(logits[0, -1], req))

    def _sample(self, logits, req: Request) -> int:
        logits = np.asarray(logits, np.float32)
        if req.temperature <= 0:
            return int(np.argmax(logits))
        self.key, k = jax.random.split(self.key)
        return int(jax.random.categorical(k, jnp.asarray(logits) / req.temperature))

    def step(self) -> List[Request]:
        """One engine iteration: admit + one decode token for all active."""
        self._admit()
        live = [i for i, r in enumerate(self.active) if r is not None]
        if not live:
            return []
        tokens = np.zeros((self.slots, 1), np.int32)
        for i in live:
            tokens[i, 0] = self.active[i].output_ids[-1]
        pos = jnp.asarray(self.pos, jnp.int32)
        logits, self.cache = self._decode(self.params, jnp.asarray(tokens),
                                          pos, self.cache)
        finished: List[Request] = []
        for i in live:
            req = self.active[i]
            nxt = self._sample(logits[i, 0], req)
            req.output_ids.append(nxt)
            self.pos[i] += 1
            self.stats["tokens_out"] += 1
            if (len(req.output_ids) >= req.max_new_tokens
                    or nxt == tok.EOS_ID
                    or self.pos[i] >= self.max_len - 1):
                req.done = True
                req.finished_at = time.time()
                finished.append(req)
                self.active[i] = None
        self.stats["steps"] += 1
        return finished


class JAXExecutor:
    """HybridFlow Executor backed by a real ServingEngine.

    Correctness still comes from the world model (we cannot grade free-form
    text without a verifier), but latency is *measured* wall-clock of real
    model execution, and cost is token-metered from real token counts —
    the integration point the paper's 'system shifts' calibration needs.

    One executor (and its engine) is shared by *all* queries in a fleet:
    each ``run`` leases a KV slot from the engine's fixed pool and steps
    only until its own request finishes (``run_until``), so requests that
    overlap in the engine decode in the same micro-batches instead of a
    call draining the whole engine. Note the fleet scheduler itself still
    dispatches ``run`` synchronously, so today co-residency only arises
    from engine-level callers; the async engine pump that overlaps fleet
    dispatch in real time is a ROADMAP open item.
    """

    def __init__(self, engine: ServingEngine, wm, cloud: bool,
                 concurrency: int = 1, price_out: float = 0.0):
        self.engine = engine
        self.wm = wm
        self.cloud = cloud
        self.concurrency = concurrency
        self.price_out = price_out

    def run(self, query, node, dep_results):
        from repro.core.scheduler import SubtaskResult, _subtask_of
        st = _subtask_of(query, node)
        prompt = node.desc + " || " + " ; ".join(
            dep_results[d].answer for d in node.deps if d in dep_results)
        t0 = time.time()
        req = self.engine.submit(prompt, max_new_tokens=min(st.tok_out, 48))
        self.engine.run_until(req)
        latency = time.time() - t0
        prof = self.wm.profile(int(self.cloud))
        p = prof.p_correct(st.difficulty)
        n_bad = sum(1 for d in node.deps
                    if d in dep_results and not dep_results[d].correct)
        p *= self.wm.parent_penalty ** n_bad
        u = self.wm._u(query, st.sid)
        n_out = len(req.output_ids)
        cost = n_out * self.price_out if self.cloud else 0.0
        return SubtaskResult(st.sid, int(self.cloud), bool(u < p), latency,
                             cost, len(req.prompt_ids), n_out,
                             answer=req.text[:120])
