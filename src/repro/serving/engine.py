"""Serving engine: batched chunked prefill + batched decode over any repro
model.

The engine serves fixed-size micro-batches with a KV cache pool:
``submit`` enqueues requests, ``step`` admits waiting requests into free
slots (continuous batching), prefills them, and advances every active
request by one decode token.

Hot path (dense decoders — the HybridFlow edge/cloud executor archs):

* **Batched chunked prefill** — a prefill planner drains all newly
  admitted slots into ONE padded ``serve_prefill_chunk`` call per step;
  prompts longer than ``prefill_chunk`` are processed one chunk per step
  so long prompts never stall co-resident decodes. KV lines are written
  directly into the shared slot-pooled cache via ``dynamic_update_slice``
  — no per-request ``init_cache`` allocation, no whole-tree copy. Under
  ``REPRO_USE_PALLAS=1`` the chunk attention runs the ragged
  chunked-prefill Pallas kernel (``stats["prefill_backend"]`` records
  which backend served the last prefill call).
* **Cross-request KV prefix reuse** — completed prompts are indexed by
  chained block hashes (``kvcache.PREFIX_BLOCK`` tokens per block); a new
  lease that shares a cached block-aligned prefix seeds its slot with ONE
  batched cross-slot copy (or skips the copy entirely when it re-leases
  its own source slot) and prefills only the uncovered tail. Matches are
  verified token-exact, a free source slot is pinned against re-lease
  until the borrower's copy launches, and at least one tail token always
  prefills — greedy outputs are bit-identical to the no-reuse path.
  ``stats["prefix_hits"]``/``["prefill_tokens_saved"]`` report the win.
* **Device-side batched sampling** — greedy/temperature sampling for all
  live slots happens inside the jitted decode/prefill step (one PRNG key
  array, one [slots] host transfer of sampled ids per step) instead of a
  per-slot ``np.asarray(logits)`` round-trip.
* **Device-resident positions** — ``pos`` lives on device as int32 and is
  advanced inside the jitted step; inactive slots are parked at
  ``max_len - 1`` (a line no live request ever attends).

Non-batchable families (moe: expert-capacity couples batch rows; vlm /
audio / hybrid / ssm: prefix or recurrent state) fall back to the legacy
per-slot batch-1 prefill, which is kept as the reference path
(``batched_prefill=False`` forces it for any family).

Engine steps are split into a *launch* phase (host builds inputs and
issues the jitted call — JAX dispatch is async) and a *commit* phase
(the one host transfer + request bookkeeping). ``step`` runs both
back-to-back; ``repro.serving.pool.EnginePool`` launches every replica
before committing any, so one replica's host-side commit overlaps the
next replica's device compute.

``JAXExecutor`` adapts an engine — or an ``EnginePool`` of replicas —
to HybridFlow's Executor protocol so the paper's scheduler can drive
*real* JAX models. It exposes both the synchronous ``run`` and the async
``submit``/``poll``/``pump`` surface the fleet scheduler's pump loop
uses to overlap subtasks from different queries in the same
micro-batches (examples/serve_hybrid). When no explicit ``concurrency``
is given it derives from the backing engine's capacity (pool: replicas ×
slots), and ``saturated()`` reports live slot occupancy so the fleet's
cloud→edge spill only fires when every replica is really full.
"""
from __future__ import annotations

import functools
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

if TYPE_CHECKING:  # the protocol lives in the package root (no cycle)
    from repro.serving import EngineLike

from repro.configs.base import ModelConfig
from repro.data import tokenizer as tok
from repro.models import kvcache as KV
from repro.models import model as M


@dataclass
class Request:
    rid: int
    prompt_ids: List[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    # filled by the engine:
    output_ids: List[int] = field(default_factory=list)
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def text(self) -> str:
        return tok.decode(self.output_ids)


@dataclass
class _PrefillJob:
    """Per-slot progress of an in-flight (possibly chunked) prefill."""

    ids: List[int]
    off: int = 0

    @property
    def remaining(self) -> int:
        return len(self.ids) - self.off


@dataclass
class _PrefillPass:
    """In-flight prefill launch awaiting its host commit."""

    jobs: List            # [(slot, _PrefillJob)] in slot order
    take: List[int]
    first: object         # device array of first sampled ids [G]


@dataclass
class _DecodePass:
    """In-flight decode launch awaiting its host commit."""

    live_slots: List[int]
    nxt: object           # device array of sampled ids [slots]


def _device_sample(logits, key, temps):
    """Greedy/temperature sampling for all slots on device. logits [B,V].

    Greedy rows (``temperature == 0.0``, the default) still flow through
    the categorical branch before the ``where``-select, so the divisor
    must stay safe for them: ``where(temps > 0, temps, 1.0)`` keeps the
    scaled logits finite (a tiny-epsilon denominator amplifies the
    padded-vocab -1e9 logits toward the float32 edge and trips
    ``jax_debug_nans`` runs; dividing by exact 0 would be inf/NaN every
    step). The sampled value of a greedy row is discarded by the select.
    """
    logits = logits.astype(jnp.float32)
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    keys = jax.random.split(key, logits.shape[0])
    safe_t = jnp.where(temps > 0, temps, 1.0)
    scaled = logits / safe_t[:, None]
    sampled = jax.vmap(jax.random.categorical)(keys, scaled).astype(jnp.int32)
    return jnp.where(temps > 0, sampled, greedy)


class _PrefixIndex:
    """Content-hashed index of the prompt prefixes currently held in the
    engine's KV slot pool.

    Prefixes are indexed at :data:`repro.models.kvcache.PREFIX_BLOCK`-token
    granularity with chained crc32 block hashes; a lookup walks the
    candidate boundaries longest-first and verifies the actual tokens
    before reporting a match, so hash collisions can never break the
    bit-identity contract. Entries are registered when a slot's prefill
    completes (its lines are then fully written and stable — decode only
    appends past the prompt) and evicted when the slot is re-leased (its
    lines are about to be overwritten from position 0).
    """

    def __init__(self, block: int):
        self.block = block
        self._slot_ids: Dict[int, tuple] = {}     # slot -> prompt token ids
        self._slot_hashes: Dict[int, tuple] = {}  # slot -> chained hashes
        self._by_hash: Dict[int, set] = {}        # chained hash -> slots

    def register(self, slot: int, ids) -> None:
        self.evict(slot)
        hs = KV.prefix_block_hashes(ids, self.block)
        if not hs:
            return
        self._slot_ids[slot] = tuple(ids)
        self._slot_hashes[slot] = tuple(hs)
        for h in hs:
            self._by_hash.setdefault(h, set()).add(slot)

    def evict(self, slot: int) -> None:
        hs = self._slot_hashes.pop(slot, None)
        self._slot_ids.pop(slot, None)
        if not hs:
            return
        for h in hs:
            slots = self._by_hash.get(h)
            if slots is not None:
                slots.discard(slot)
                if not slots:
                    del self._by_hash[h]

    def match(self, ids) -> "tuple[Optional[int], int]":
        """(slot, n_tokens) of the longest cached block-aligned PROPER
        prefix of ``ids`` — capped at ``len(ids) - 1`` so at least one
        tail token always prefills (the first sampled token comes from
        the last prompt token's prefill logits)."""
        hs = KV.prefix_block_hashes(ids, self.block)
        usable = min(len(hs), (len(ids) - 1) // self.block)
        for b in range(usable, 0, -1):
            slots = self._by_hash.get(hs[b - 1])
            if not slots:
                continue
            n = b * self.block
            want = tuple(ids[:n])
            for slot in sorted(slots):
                if self._slot_ids.get(slot, ())[:n] == want:
                    return slot, n
        return None, 0


# Jitted cross-slot prefix-copy steps, one per static gather width (the
# same power-of-two bucket ladder as chunk widths). Module-level so pool
# replicas and fleet reruns share compiles; _track_retraces folds their
# signature counts into stats["jit_retraces"]. Pool replicas pump on
# ThreadPoolExecutor workers, so the dict is mutated concurrently with
# another replica's _track_retraces iteration — all access goes through
# _COPY_LOCK (declared below, enforced by reprolint's thread-ownership
# rule).
_COPY_JITS: Dict[int, object] = {}
_COPY_LOCK = threading.Lock()
_MODULE_OWNERSHIP = {"_COPY_JITS": "shared-lock:_COPY_LOCK"}


def _jit_copy(width: int):
    with _COPY_LOCK:
        fn = _COPY_JITS.get(width)
        if fn is None:
            def copy_fn(cache, src_idx, dst_idx, length):
                k, v = KV.copy_prefix(cache["k"], cache["v"], src_idx,
                                      dst_idx, length, width)
                return dict(cache, k=k, v=v)
            fn = jax.jit(copy_fn, donate_argnums=(0,))
            _COPY_JITS[width] = fn
        return fn


@functools.lru_cache(maxsize=64)
def _jit_steps(cfg: ModelConfig, max_len: int, use_pallas: bool = False):
    """Fused decode+sample and chunk-prefill+sample steps, jitted once per
    (config, max_len, attention backend) and shared by every engine
    instance — compile cache survives engine churn (fleet drivers build
    engine pairs per run). ``use_pallas`` is part of the cache key because
    the kernel dispatch is read at trace time: without it, toggling
    ``pallas_enabled`` after a reference-path compile would silently keep
    serving XLA programs."""

    def decode_fn(params, tokens, pos, cache, key, temps, live):
        # park inactive/prefilling slots at max_len-1: their garbage write
        # lands on a line no live request ever attends (requests finish at
        # pos >= max_len-1 before reading it)
        pos_eff = jnp.where(live > 0, pos, max_len - 1)
        logits, cache = M.serve_decode(params, cfg, tokens, pos_eff, cache)
        key, sub = jax.random.split(key)
        nxt = _device_sample(logits[:, 0], sub, temps)
        return nxt, pos + live, cache, key

    def prefill_fn(params, tokens, slot_idx, pos0, take, pos, cache, key,
                   temps, kv_width):
        logits, cache = M.serve_prefill_chunk(params, cfg, tokens, cache,
                                              slot_idx, pos0, take,
                                              kv_width=kv_width)
        key, sub = jax.random.split(key)
        first = _device_sample(logits[:, 0], sub, temps)
        pos = pos.at[slot_idx].set(pos0 + take)
        return first, pos, cache, key

    # donate pos + cache: XLA aliases the buffers, so the per-step KV
    # update is in place instead of a full-pool copy; kv_width is static
    # (a power-of-two bucket) so attention shapes stay bounded
    return (jax.jit(decode_fn, donate_argnums=(2, 3)),
            jax.jit(prefill_fn, donate_argnums=(5, 6),
                    static_argnums=(9,)))


class ServingEngine:
    """Slot-based continuous batching engine for one model."""

    # Concurrency contract, enforced statically by reprolint's
    # thread-ownership rule (tools/reprolint/README.md): when this
    # engine is an EnginePool replica, step()/pump() run on a
    # ThreadPoolExecutor worker, so everything the step path touches is
    # replica-private — owned by that worker while a pool pump is in
    # flight, and never reachable through another object reference from
    # code running concurrently with workers.
    _THREAD_OWNERSHIP = {
        "cache": "replica-private",
        "pos": "replica-private",
        "_pos_np": "replica-private",
        "key": "replica-private",
        "active": "replica-private",
        "queue": "replica-private",
        "_prefilling": "replica-private",
        "_pending_copy": "replica-private",
        "_pinned": "replica-private",
        "_prefix": "replica-private",
        "_slot_used": "replica-private",
        "stats": "replica-private",
    }
    # worker-thread entry points; reprolint closes the set over self.x()
    # calls, so every helper the step path reaches is checked too
    _WORKER_METHODS = ("step", "pump")

    def __init__(self, cfg: ModelConfig, params, *, batch_slots: int = 4,
                 max_len: int = 512, dtype=jnp.float32, seed: int = 0,
                 prefill_chunk: Optional[int] = None,
                 batched_prefill: bool = True,
                 prefix_reuse: bool = True,
                 prefix_block: int = KV.PREFIX_BLOCK):
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.dtype = dtype
        self.seed = seed
        # raw ctor args so EnginePool can clone replicas (shared params,
        # independent KV slot pools); batched_prefill below is ANDed with
        # the family gate, so keep the caller's value here
        self._ctor_kw = dict(batch_slots=batch_slots, max_len=max_len,
                             dtype=dtype, prefill_chunk=prefill_chunk,
                             batched_prefill=batched_prefill,
                             prefix_reuse=prefix_reuse,
                             prefix_block=prefix_block)
        self.key = jax.random.PRNGKey(seed)
        self.cache = M.init_cache(cfg, batch_slots, max_len, dtype=dtype)
        # device-resident next positions (int32), parked at max_len-1 for
        # slots with no live request; host mirror for cheap finish checks
        self.pos = jnp.full((batch_slots,), max_len - 1, jnp.int32)
        self._pos_np = np.full(batch_slots, max_len - 1, np.int32)
        self.prefill_chunk = (None if prefill_chunk is None
                              else max(1, min(prefill_chunk, max_len)))
        self.batched_prefill = (batched_prefill and
                                cfg.family in M.CHUNKED_PREFILL_FAMILIES)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.queue: List[Request] = []
        self._rid = 0
        self._slot_used = [False] * batch_slots
        self._prefilling: Dict[int, _PrefillJob] = {}
        # cross-request KV prefix reuse: only the batched-prefill fast path
        # can seed a slot (the legacy path rebuilds a batch-1 cache from
        # scratch). The slot pool itself is always absolute-positioned in
        # the serving regime (pos < max_len, so even windowed configs
        # write line pos % M == pos), and window masking reads the same
        # lines either way — content-identical caches keep bit-identity.
        self.prefix_block = max(1, prefix_block)
        self.prefix_reuse = bool(prefix_reuse and self.batched_prefill)
        self._prefix = _PrefixIndex(self.prefix_block)
        self._pending_copy: List[tuple] = []   # (dst_slot, src_slot, n)
        self._pinned: set = set()              # copy sources awaiting launch
        self.stats = {"tokens_out": 0, "prefill_tokens": 0, "steps": 0,
                      "slot_reuses": 0, "peak_active": 0, "requests": 0,
                      "prefill_calls": 0, "prefill_batch_max": 0,
                      "prefill_backend": None, "jit_retraces": 0,
                      "prefix_hits": 0, "prefill_tokens_saved": 0,
                      "prefix_copies": 0}

    def _steps(self):
        """Resolve the jitted step pair against the CURRENT kernel-dispatch
        state (lru-cached, so this is a dict hit per tick)."""
        from repro.kernels import dispatch as kd
        return _jit_steps(self.cfg, self.max_len, kd.use_pallas())

    def _track_retraces(self) -> None:
        """Record how many signatures the shared jitted step pair has
        compiled. Every distinct (g, width, kv_width) prefill shape and
        every decode shape is one XLA program; all three come off static
        bucket ladders (g <= slots; width/kv_width powers of two capped
        at max_len), so this must stay bounded for ANY fleet mix — the
        regression test pins the bound. The count is the lru-shared
        truth for this (cfg, max_len, backend) key, so engine churn
        (pool replicas, fleet reruns) must not grow it either."""
        decode_step, prefill_step = self._steps()
        self.stats["jit_retraces"] = (decode_step._cache_size()
                                      + prefill_step._cache_size())
        # prefix-seed copy compiles tracked separately: _COPY_JITS is
        # shared process-wide across (cfg, max_len) shapes, so folding it
        # into jit_retraces would couple one engine's bound to every
        # other engine's compile history. Its ladder is (g, width) —
        # bounded exactly like prefill — and the no-new-compiles-on-rerun
        # contract is pinned by the retrace regression test. Snapshot the
        # shared dict under its lock: another pool replica's worker may
        # be inserting a new width mid-iteration.
        with _COPY_LOCK:
            fns = list(_COPY_JITS.values())
        self.stats["prefix_seed_compiles"] = sum(
            fn._cache_size() for fn in fns)

    def clone(self, *, seed: Optional[int] = None) -> "ServingEngine":
        """A fresh engine over the SAME config and params (no re-init)
        with its own KV slot pool — the EnginePool replica constructor."""
        return ServingEngine(self.cfg, self.params,
                             seed=self.seed if seed is None else seed,
                             **self._ctor_kw)

    # ---- public API ---------------------------------------------------
    def submit(self, prompt: "str | List[int]", *, max_new_tokens: int = 32,
               temperature: float = 0.0,
               prefix_hint: Optional[List[int]] = None) -> Request:
        # prefix_hint is a pool/scheduler affinity signal (see
        # EnginePool.submit); a single engine matches against the actual
        # prompt at admit time, so the hint is accepted and ignored here.
        del prefix_hint
        if max_new_tokens >= self.max_len - 1:
            raise ValueError(
                f"max_new_tokens={max_new_tokens} leaves no room for the "
                f"prompt in a max_len={self.max_len} cache (need "
                f"max_new_tokens <= max_len - 2)")
        ids = tok.encode(prompt) if isinstance(prompt, str) else list(prompt)
        ids = [min(i, self.cfg.vocab_size - 1) for i in ids]
        req = Request(self._rid, ids, max_new_tokens, temperature,
                      submitted_at=time.time())
        req._engine = self            # ownership marker for run_until
        self._rid += 1
        self.queue.append(req)
        return req

    @property
    def has_work(self) -> bool:
        return bool(self.queue) or any(a is not None for a in self.active)

    @property
    def capacity(self) -> int:
        """KV slots this engine can decode concurrently (pool symmetry)."""
        return self.slots

    @property
    def load(self) -> int:
        """Requests holding or waiting on a slot (active + queued)."""
        return self.n_active + len(self.queue)

    # reprolint: hot
    def pump(self) -> bool:
        """Advance one step if there is work. Returns progress (the same
        surface ``EnginePool.pump`` exposes for a whole replica set)."""
        if self.has_work:
            self.step()
            return True
        return False

    def saturated(self) -> bool:
        """EngineLike surface: every KV slot is leased (a pool is
        saturated only when every replica is)."""
        return self.load >= self.slots

    def run_until_done(self, max_steps: int = 10_000) -> List[Request]:
        done: List[Request] = []
        for _ in range(max_steps):
            if not self.has_work:
                break
            done.extend(self.step())
        return done

    def run_until(self, req: Request, max_steps: int = 10_000) -> Request:
        """Step the engine until ``req`` finishes (continuous batching:
        co-resident requests from other queries advance on the same decode
        steps — the fleet runtime's slot-sharing entry point)."""
        if getattr(req, "_engine", None) is not self:
            raise ValueError(
                f"request {req.rid} was never submitted to this engine "
                f"(submit() returns the Request object to wait on)")
        for _ in range(max_steps):
            if req.done:
                return req
            if not self.has_work:
                raise RuntimeError(
                    f"engine drained with request {req.rid} unfinished "
                    f"(engine bug: an owned request left the queue)")
            self.step()
        if req.done:
            return req
        raise RuntimeError(f"request {req.rid} did not finish "
                           f"within {max_steps} engine steps")

    @property
    def n_active(self) -> int:
        return sum(a is not None for a in self.active)

    def prefix_match_len(self, ids) -> int:
        """Longest block-aligned cached prefix (in tokens) this engine
        could seed for ``ids`` right now — the pool's affinity signal.
        Read-only: no pin, no eviction, no stats."""
        if not self.prefix_reuse or not ids:
            return 0
        _, n = self._prefix.match(list(ids))
        return n

    def cancel(self, req: Request) -> bool:
        """Withdraw a request: drop it from the admission queue, or free
        its KV slot (and any in-progress prefill) if already resident —
        the slot returns to the pool on the next admit. Decoded tokens
        stay on the request; the caller decides whether to discard them.
        Safe to call on an already-finished or foreign request (no-op,
        returns False)."""
        for j, q in enumerate(self.queue):
            if q is req:
                self.queue.pop(j)
                req._engine = None
                return True
        for slot, r in enumerate(self.active):
            if r is req:
                self.active[slot] = None
                self._prefilling.pop(slot, None)
                # drop any not-yet-launched prefix seed targeting this slot
                # and recompute pins (a source stays pinned only while some
                # other borrower still needs it)
                if self._pending_copy:
                    self._pending_copy = [c for c in self._pending_copy
                                          if c[0] != slot]
                    self._pinned = {src for _, src, _ in self._pending_copy}
                req._engine = None
                return True
        return False

    # ---- engine internals ----------------------------------------------
    def _admit(self) -> None:
        for slot in range(self.slots):
            if (self.active[slot] is None and self.queue
                    and slot not in self._pinned):
                req = self.queue.pop(0)
                self.active[slot] = req
                # slot lease accounting: KV lines are a fixed pool; a
                # reused slot means the cache allocation was recycled
                # rather than grown (the bounded-pool invariant)
                if self._slot_used[slot]:
                    self.stats["slot_reuses"] += 1
                self._slot_used[slot] = True
                self.stats["requests"] += 1
                ids = req.prompt_ids[-(self.max_len - req.max_new_tokens - 1):]
                if self.batched_prefill:
                    job = _PrefillJob(ids)
                    if self.prefix_reuse:
                        # match BEFORE evicting this slot's own entry: if
                        # the best source is the slot we just leased, its
                        # prefix lines are already in place (in-place
                        # reuse, no copy); otherwise pin the source so no
                        # later lease overwrites it before the batched
                        # seed copy launches.
                        src, n = self._prefix.match(ids)
                        if n > 0:
                            job.off = n
                            self.stats["prefix_hits"] += 1
                            self.stats["prefill_tokens_saved"] += n
                            if src != slot:
                                self._pending_copy.append((slot, src, n))
                                self._pinned.add(src)
                        self._prefix.evict(slot)
                    self._prefilling[slot] = job
                else:
                    self._prefill_slot_legacy(slot, req, ids)
        self.stats["peak_active"] = max(self.stats["peak_active"],
                                        self.n_active)

    def _bucket(self, n: int) -> int:
        """Pad chunk width to a power-of-two bucket (bounded jit compiles)."""
        b = 8
        while b < n:
            b *= 2
        return min(b, self.max_len)

    # reprolint: hot
    def _prefill_launch(self) -> Optional[_PrefillPass]:
        """Launch one chunk for every prefilling slot — a single padded
        ``serve_prefill_chunk`` call for the whole group. Host bookkeeping
        is deferred to ``_prefill_commit`` so a pool can overlap another
        replica's launch with this one's device compute."""
        if self._pending_copy:
            # seed newly leased slots from their matched sources in ONE
            # batched copy, issued BEFORE this step's prefill writes: the
            # copy reads the pre-step cache value (XLA data ordering), so
            # even a source re-leased in the same admit pass is read
            # intact. Pins release here — after this the borrowers own
            # their lines and sources are free to be overwritten.
            dst = np.asarray([c[0] for c in self._pending_copy], np.int32)
            src = np.asarray([c[1] for c in self._pending_copy], np.int32)
            ln = np.asarray([c[2] for c in self._pending_copy], np.int32)
            width = self._bucket(int(ln.max()))
            self.cache = _jit_copy(width)(
                self.cache, jnp.asarray(src), jnp.asarray(dst),
                jnp.asarray(ln))  # donate+rebind: reprolint-clean idiom
            self.stats["prefix_copies"] += len(self._pending_copy)
            self._pending_copy.clear()
            self._pinned.clear()
            self._track_retraces()
        if not self._prefilling:
            return None
        jobs = sorted(self._prefilling.items())
        chunk = self.prefill_chunk or self.max_len
        take = [min(j.remaining, chunk) for _, j in jobs]
        width = self._bucket(max(take))
        g = len(jobs)
        tokens = np.zeros((g, width), np.int32)
        pos0 = np.zeros(g, np.int32)
        slot_idx = np.zeros(g, np.int32)
        temps = np.zeros(g, np.float32)
        for i, (slot, j) in enumerate(jobs):
            tokens[i, :take[i]] = j.ids[j.off:j.off + take[i]]
            pos0[i] = j.off
            slot_idx[i] = slot
            temps[i] = self.active[slot].temperature
        # kv_width is pinned to the SAME power-of-two bucket ladder as the
        # chunk width (static jit arg): a mixed-length fleet can only ever
        # produce O(log(max_len)) distinct kv_width values, so the
        # (g, width, kv_width) retrace space stays bounded no matter how
        # prompt lengths vary step to step (stats["jit_retraces"]).
        kv_width = self._bucket(int(max(pos0[i] + take[i]
                                        for i in range(g))))
        from repro.kernels import dispatch as kd
        self.stats["prefill_backend"] = "pallas" if kd.use_pallas() else "xla"
        _, prefill_step = self._steps()
        first, self.pos, self.cache, self.key = prefill_step(
            self.params, jnp.asarray(tokens), jnp.asarray(slot_idx),
            jnp.asarray(pos0),
            # host->device upload of a Python list, not a device sync
            # reprolint: disable=host-sync-in-hot-path -- take is a host list; np.asarray builds the upload buffer
            jnp.asarray(np.asarray(take, np.int32)),
            self.pos, self.cache, self.key, jnp.asarray(temps), kv_width)
        self.stats["prefill_calls"] += 1
        self.stats["prefill_batch_max"] = max(
            self.stats["prefill_batch_max"], g)
        self._track_retraces()
        return _PrefillPass(jobs, take, first)

    # reprolint: hot
    def _prefill_commit(self, p: _PrefillPass) -> None:
        """Sync the launched prefill chunk and advance the per-slot jobs
        (first sampled token, slot positions, finished-job retirement)."""
        # reprolint: disable=host-sync-in-hot-path -- the ONE host transfer per prefill pass (sampled first tokens)
        first_np = np.asarray(p.first)
        for i, (slot, j) in enumerate(p.jobs):
            j.off += p.take[i]
            self.stats["prefill_tokens"] += p.take[i]
            if j.remaining == 0:
                self.active[slot].output_ids.append(int(first_np[i]))
                self._pos_np[slot] = len(j.ids)
                if self.prefix_reuse:
                    # the slot's prompt lines are now fully written (and
                    # stable: decode only appends past them) — publish
                    # them for later leases to borrow
                    self._prefix.register(slot, j.ids)
                del self._prefilling[slot]

    def _prefill_slot_legacy(self, slot: int, req: Request,
                             ids: List[int]) -> None:
        """Single-request batch-1 prefill + slot copy — the reference path
        for families without chunked-slot prefill support."""
        batch = {"tokens": jnp.asarray([ids], jnp.int32)}
        if self.cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros(
                (1, self.cfg.n_image_patches, self.cfg.d_model), self.dtype)
        if self.cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (1, self.cfg.encoder_seq, self.cfg.d_model), self.dtype)
        cache1 = M.init_cache(self.cfg, 1, self.max_len, dtype=self.dtype)
        logits, cache1 = M.serve_prefill(self.params, self.cfg, batch, cache1)
        # copy slot lines: every cache leaf has batch at axis -? => leaves
        # follow [L, B, ...] or [B, ...]; match by dim size
        def write(dst, src):
            if dst.ndim >= 2 and dst.shape[1] == self.slots and src.shape[1] == 1:
                return dst.at[:, slot].set(src[:, 0])
            if dst.shape[0] == self.slots and src.shape[0] == 1:
                return dst.at[slot].set(src[0])
            # nested stacks ([G, m, B, ...]): search batch axis
            for ax in range(dst.ndim):
                if dst.shape[ax] == self.slots and src.shape[ax] == 1:
                    idx = tuple([slice(None)] * ax + [slot])
                    sidx = tuple([slice(None)] * ax + [0])
                    return dst.at[idx].set(src[sidx])
            raise ValueError(f"no batch axis: {dst.shape} <- {src.shape}")

        self.cache = jax.tree.map(write, self.cache, cache1)
        self.stats["prefill_backend"] = "legacy-batch1"
        n_img = self.cfg.n_image_patches if self.cfg.family == "vlm" else 0
        n = len(ids) + n_img
        self.pos = self.pos.at[slot].set(n)
        self._pos_np[slot] = n
        self.stats["prefill_tokens"] += len(ids)
        req.output_ids.append(self._sample_host(logits[0, -1], req))

    # reprolint: hot
    def _sample_host(self, logits, req: Request) -> int:
        """Host-side sampling (legacy prefill path only)."""
        # reprolint: disable=host-sync-in-hot-path -- legacy batch-1 path samples on host by design (reference behavior)
        logits = np.asarray(logits, np.float32)
        if req.temperature <= 0:
            return int(np.argmax(logits))
        self.key, k = jax.random.split(self.key)
        # reprolint: disable=host-sync-in-hot-path -- legacy path: one sampled id comes back to host here
        return int(jax.random.categorical(
            k, jnp.asarray(logits) / req.temperature))

    # reprolint: hot
    def _decode_launch(self) -> Optional[_DecodePass]:
        """Launch one decode token for every live (fully prefilled) slot;
        host bookkeeping is deferred to ``_decode_commit``."""
        live_slots = [i for i, r in enumerate(self.active)
                      if r is not None and i not in self._prefilling]
        if not live_slots:
            return None
        tokens = np.zeros((self.slots, 1), np.int32)
        temps = np.zeros(self.slots, np.float32)
        live = np.zeros(self.slots, np.int32)
        for i in live_slots:
            tokens[i, 0] = self.active[i].output_ids[-1]
            temps[i] = self.active[i].temperature
            live[i] = 1
        decode_step, _ = self._steps()
        nxt, self.pos, self.cache, self.key = decode_step(
            self.params, jnp.asarray(tokens), self.pos, self.cache,
            self.key, jnp.asarray(temps), jnp.asarray(live))
        self._track_retraces()
        return _DecodePass(live_slots, nxt)

    # reprolint: hot
    def _decode_commit(self, d: _DecodePass) -> List[Request]:
        """Sync the launched decode step and retire finished requests."""
        # reprolint: disable=host-sync-in-hot-path -- the ONE host transfer per decode step (sampled ids)
        nxt_np = np.asarray(d.nxt)
        finished: List[Request] = []
        for i in d.live_slots:
            req = self.active[i]
            req.output_ids.append(int(nxt_np[i]))
            self._pos_np[i] += 1
            self.stats["tokens_out"] += 1
            if (len(req.output_ids) >= req.max_new_tokens
                    or nxt_np[i] == tok.EOS_ID
                    or self._pos_np[i] >= self.max_len - 1):
                req.done = True
                req.finished_at = time.time()
                finished.append(req)
                self.active[i] = None
        self.stats["steps"] += 1
        return finished

    # reprolint: hot
    def step(self) -> List[Request]:
        """One engine iteration: admit waiting requests, advance every
        prefilling slot by one chunk, then decode one token for all live
        slots (prefill and decode of co-resident requests interleave, so
        a long prompt never stalls running generations)."""
        self._admit()
        p = self._prefill_launch()
        if p is not None:
            self._prefill_commit(p)
        d = self._decode_launch()
        return self._decode_commit(d) if d is not None else []


@dataclass
class _Inflight:
    """Future for one subtask submitted to a JAXExecutor engine."""

    req: Request
    sid: int
    cloud: bool
    difficulty: float
    n_bad_parents: int
    query: object
    t0: float


class JAXExecutor:
    """HybridFlow Executor backed by a real ServingEngine or EnginePool.

    Correctness still comes from the world model (we cannot grade free-form
    text without a verifier), but latency is *measured* wall-clock of real
    model execution, and cost is token-metered from real token counts —
    the integration point the paper's 'system shifts' calibration needs.

    One executor (and its engine/pool) is shared by *all* queries in a
    fleet: each subtask leases a KV slot from a fixed slot pool. Two ways
    to drive it:

    * ``run`` — synchronous: submits and steps the engine until the
      subtask's own request finishes (``run_until``); co-residency then
      only arises from engine-level callers.
    * ``submit``/``poll``/``pump`` — the async surface the fleet
      scheduler's pump loop uses: ``submit`` enqueues and returns a
      future, ``pump`` advances the engine (every pool replica with
      pending work) one step, ``poll`` collects a finished future.
      Subtasks from different queries submitted before the next pump
      decode in the SAME micro-batches, so wall-clock tracks the
      simulated makespan instead of serializing.

    ``concurrency=None`` derives the dispatch width from the backing
    capacity — ``slots`` for a single engine, ``replicas × slots`` for an
    ``EnginePool`` — so the fleet scheduler admits exactly as many
    subtasks as there are KV slots. ``saturated()`` reports live slot
    occupancy: the scheduler's cloud→edge spill consults it so spill only
    fires when *every* replica is really full, not merely when the
    scheduler's own busy count hit an explicit (possibly narrower)
    ``concurrency`` cap.
    """

    def __init__(self, engine: "EngineLike", wm, cloud: bool,
                 concurrency: Optional[int] = None, price_out: float = 0.0):
        self.engine: "EngineLike" = engine
        self.wm = wm
        self.cloud = cloud
        # derived caps track capacity if the engine is later pooled; an
        # explicit cap is a caller admission policy and must survive it
        self.derived_concurrency = concurrency is None
        self.concurrency = engine.capacity if concurrency is None \
            else concurrency
        self.price_out = price_out

    def saturated(self) -> bool:
        """True when no replica has a free KV slot (spill eligibility).
        Uniform across backings: ``EngineLike.saturated()`` is the
        protocol method both ``ServingEngine`` and ``EnginePool``
        implement, so there is no engine-vs-pool branching here."""
        return bool(self.engine.saturated())

    # sibling subtasks of one query share this many leading characters of
    # query context verbatim, so their prompts hash to the same KV prefix
    # blocks (kept short: engine prompts are tail-truncated to the KV
    # budget, and a truncated-away context can never be shared)
    QUERY_CTX_CHARS = 40

    # advertises the ``prefix_hint=`` submit kwarg to the fleet scheduler
    # (analytic executors don't take it; the scheduler feature-detects)
    accepts_prefix_hint = True

    @classmethod
    def query_context(cls, query) -> str:
        """The verbatim prompt prefix every sibling subtask of ``query``
        starts with — the DAG-level shared context."""
        txt = getattr(query, "text", "") or ""
        return (txt[:cls.QUERY_CTX_CHARS] + " >> ") if txt else ""

    def shared_context(self, query) -> List[int]:
        """Token ids of :meth:`query_context` — the prefix hint the fleet
        scheduler pins on a dispatch and carries across retry, cloud→edge
        spill, and degradation re-dispatch."""
        return tok.encode(self.query_context(query))

    # ---- async surface (fleet pump loop) -------------------------------
    def submit(self, query, node, dep_results, *,
               prefix_hint: Optional[List[int]] = None) -> _Inflight:
        from repro.core.scheduler import _subtask_of
        st = _subtask_of(query, node)
        prompt = self.query_context(query) + node.desc + " || " + " ; ".join(
            dep_results[d].answer for d in node.deps if d in dep_results)
        n_bad = sum(1 for d in node.deps
                    if d in dep_results and not dep_results[d].correct)
        req = self.engine.submit(prompt, max_new_tokens=min(st.tok_out, 48),
                                 prefix_hint=prefix_hint)
        return _Inflight(req, st.sid, self.cloud, st.difficulty, n_bad,
                         query, time.perf_counter())

    # reprolint: hot
    def pump(self) -> bool:
        """Advance the engine (or every loaded pool replica) one step if
        it has work. Returns progress."""
        return bool(self.engine.pump())

    def cancel(self, h: _Inflight) -> bool:
        """Withdraw a (timed-out) attempt so its KV slot frees now — the
        fleet scheduler's deadline path calls this before re-dispatch."""
        return bool(self.engine.cancel(h.req))

    def attempt_cost(self, h: _Inflight) -> float:
        """$ already sunk into an attempt: tokens decoded so far. The
        scheduler charges this for abandoned (timed-out) attempts so the
        budget model stays honest under faults."""
        return len(h.req.output_ids) * self.price_out if self.cloud else 0.0

    # reprolint: hot
    def poll(self, h: _Inflight):
        """Collect a finished future; None while still decoding."""
        if not h.req.done:
            return None
        from repro.core.scheduler import SubtaskResult
        latency = time.perf_counter() - h.t0
        prof = self.wm.profile(int(self.cloud))
        p = prof.p_correct(h.difficulty)
        p *= self.wm.parent_penalty ** h.n_bad_parents
        u = self.wm._u(h.query, h.sid)
        n_out = len(h.req.output_ids)
        cost = n_out * self.price_out if self.cloud else 0.0
        return SubtaskResult(h.sid, int(self.cloud), bool(u < p), latency,
                             cost, len(h.req.prompt_ids), n_out,
                             answer=h.req.text[:120])

    # ---- synchronous surface (Executor protocol) -----------------------
    def run(self, query, node, dep_results):
        h = self.submit(query, node, dep_results)
        self.engine.run_until(h.req)
        return self.poll(h)
