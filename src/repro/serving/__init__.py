"""Real-model serving: slot-batched engines, replicated (and elastic)
engine pools, the Executor adapter, the multi-query fleet runtime,
open-loop traffic traces, and deterministic fault injection.

Surface overview
----------------
* :class:`ServingRuntime` — admit → plan → fleet-execute. Every knob
  lives on the frozen :class:`ServingConfig` value object
  (``ServingRuntime(edge, cloud, policy, planner=..., config=...)``);
  that is the entire constructor surface — the PR 8 flat-kwargs
  deprecation shim is gone and any other kwarg raises ``TypeError``.
  One dispatcher serves every mode:
  ``serve(queries)`` (closed loop), ``serve(queries, mode="sequential")``
  and ``serve(queries, arrivals=trace)`` / ``serve_trace(trace)`` (open
  loop with timed admission) all return the same
  :class:`RuntimeReport` shape.
* :class:`~repro.serving.traffic.Trace` /
  :class:`~repro.serving.traffic.Phase` — seeded arrival schedules
  (Poisson at a target RPS, day-cycle ramps/peaks, bursts, zero-traffic
  gaps), JSON round-trip replayable, wall-clock ``scaled()`` for tests.
* :class:`EnginePool` — R engine replicas behind one engine surface;
  ``arm_autoscale(AutoscalePolicy(...))`` (or
  ``ServingConfig(replicas=R, autoscale=...)``) makes it elastic:
  occupancy-driven grow/shrink with a modeled
  :class:`~repro.serving.pool.ColdStartModel`, scale-to-zero on traffic
  gaps and poke-to-warm on the first arrival after one.
* :class:`EngineLike` — the explicit protocol every engine backing
  implements (below).

EngineLike protocol
-------------------
``JAXExecutor`` types against :class:`EngineLike`, not against a
concrete engine or pool — anything implementing the protocol can back
an executor:

* ``submit(prompt, **kw) -> Request`` — enqueue; the returned request
  object IS the future (``req.done`` / ``req.text``; result *polling*
  is the executor's job, built on ``req.done``)
* ``step() -> list[Request]`` — one admit/prefill/decode pass;
  returns newly finished requests
* ``pump() -> bool`` — step only if there is work; returns progress
  (the fleet loop's per-pass entry point)
* ``cancel(req) -> bool`` — withdraw a request, freeing its KV slot
* ``saturated() -> bool`` — live occupancy: no free KV slot anywhere
  (the fleet's cloud→edge spill consults exactly this)
* ``run_until(req) -> Request`` — synchronous drain for one request
* ``capacity`` / ``load`` / ``has_work`` / ``stats`` — slot capacity,
  active+queued requests, pending-work flag, counters dict

``ServingEngine`` (one KV slot pool) and ``EnginePool`` (R replicas)
both declare it — asserted at import time below and checkable at
runtime via ``isinstance(x, EngineLike)``.

KV prefix-reuse contract
------------------------
Dense-decoder engines reuse KV lines across requests whose prompts share
a prefix (``prefix_reuse=True`` by default on the batched-prefill path):

* **Granularity**: prefixes are hashed per
  :data:`repro.models.kvcache.PREFIX_BLOCK`-token block (chained crc32);
  a lease can only skip whole matched blocks, capped one token short of
  its own prompt (the first sampled token needs the last prompt token's
  prefill logits). Every hash match is verified token-exact before use,
  so collisions cannot break bit-identity: greedy reuse-on outputs equal
  reuse-off outputs token for token.
* **Lifecycle & eviction pinning**: a slot's prompt is registered when
  its prefill completes (lines fully written; decode only appends past
  them) and evicted when the slot is re-leased. A *free* slot whose
  lines a newly admitted borrower matched is **pinned** — skipped by
  admission — until the borrower's batched seed copy launches (same
  step), so a concurrent lease can never overwrite a borrowed prefix
  mid-copy. A borrower that re-leases its own best source reuses the
  lines in place (no copy at all).
* **Pool affinity**: each ``EnginePool`` replica owns its index;
  ``submit(prefix_hint=...)`` (the fleet scheduler's DAG hint, carried
  across retry / spill / degradation re-dispatch) breaks least-loaded
  ties toward the replica holding the longest cached prefix — affinity
  never outranks load or health.
* **What failover invalidates**: a dead replica's index dies with its
  KV pool — failed-over requests restart from the prompt on a survivor
  and simply re-match whatever that survivor's index holds. Cancelling
  a mid-prefill request drops its pending seed copy and releases any
  pin it held; nothing is ever registered for partially written lines.

``stats["prefix_hits"]`` / ``["prefill_tokens_saved"]`` /
``["prefix_copies"]`` report the reuse win per engine (summed across a
pool; surfaced as ``edge_``/``cloud_``-prefixed report stats by the
runtime).

Failure-semantics contract
--------------------------
The serving stack absorbs failures at three layers; each layer has a
fixed answer to "what retries, what degrades, what surfaces":

* **Subtask attempts** (``core.scheduler.RetryPolicy``): an executor
  exception on ``run``/``submit`` or a per-attempt deadline
  (``timeout_s``) overrun **retries** with capped exponential backoff,
  up to ``max_retries`` times per side. Timed-out attempts are cancelled
  (the KV slot frees) and their sunk cost — tokens already decoded — is
  charged to the per-query and global budgets.
* **Cloud exhaustion** (graceful degradation): a *cloud* subtask out of
  retries **degrades** to the edge executor through the same offload
  bookkeeping the spill path uses, with a fresh attempt budget; its
  ``SubtaskResult`` records ``degraded=True`` and the absorbed
  ``retries``. Only an *edge*-side exhaustion (or
  ``degrade_to_edge=False``) **surfaces** as a ``RuntimeError``.
* **Pool replicas** (``EnginePool``): a replica whose pump step raises is
  marked **dead** — the worker-thread exception is captured at the join,
  never lost — and its in-flight requests **fail over** to the
  least-loaded survivor (restarted from the prompt; generation state
  died with the replica's KV slots). A replica holding work without
  progress for ``suspect_after`` passes turns **suspect**: its work is
  hedged onto healthy replicas and dispatch deprioritizes it until it
  recovers. Only all-replicas-dead (or ``failover=False``) surfaces.
  Elastic lifecycle states (warm/warming/cold) are orthogonal to health:
  failover and hedging target warm replicas, straggler detection skips
  replicas that are merely warming.

With ``retry=None`` and no faults, every fault path is provably inert:
runs are bit-identical to the pre-fault-tolerance stack (chaos suite:
``tests/test_faults.py``). ``serving.faults`` provides the seeded
``FaultPlan``/``FaultInjector`` chaos harness that exercises all of the
above reproducibly (``launch/serve.py --faults``).

Thread-ownership annotations
----------------------------
The "replica state strictly thread-private, results joined in replica
order" contract behind all of the above is *declared in code* and
checked statically by the gating ``reprolint`` CI job:
``ServingEngine`` and ``EnginePool`` carry ``_THREAD_OWNERSHIP`` /
``_WORKER_METHODS`` / ``_CONCURRENT_METHODS`` class attributes mapping
each attribute to its ownership domain (``replica-private``,
``join-only``, ``shared-lock:<lockattr>``), and module-level shared
state (e.g. the ``_COPY_JITS`` compile cache) declares its lock via
``_MODULE_OWNERSHIP``.  New engine/pool state MUST be added to those
maps; see ``tools/reprolint/README.md`` for the domain semantics and
the thread-ownership rule catalog entry.
"""
from typing import List, Protocol, runtime_checkable

from repro.core.scheduler import RetryPolicy
from repro.serving.engine import JAXExecutor, Request, ServingEngine
from repro.serving.faults import (FaultError, FaultInjector, FaultPlan,
                                  InjectedFault)
from repro.serving.pool import (AutoscalePolicy, Autoscaler, ColdStartModel,
                                EnginePool)
from repro.serving.runtime import (RuntimeReport, ServingConfig,
                                   ServingRuntime)
from repro.serving.traffic import Phase, Trace, day_cycle


@runtime_checkable
class EngineLike(Protocol):
    """What ``JAXExecutor`` (and the fleet loop through it) requires of
    an engine backing — see the module docstring for the semantics of
    each member. Implemented by ``ServingEngine`` and ``EnginePool``."""

    @property
    def capacity(self) -> int: ...

    @property
    def load(self) -> int: ...

    @property
    def has_work(self) -> bool: ...

    @property
    def stats(self) -> dict: ...

    def submit(self, prompt, **kw) -> Request: ...

    def step(self) -> List[Request]: ...

    def pump(self) -> bool: ...

    def cancel(self, req: Request) -> bool: ...

    def saturated(self) -> bool: ...

    def run_until(self, req: Request, max_steps: int = 10_000) -> Request: ...


# both backings declare the protocol; catching a drift here (at import
# time) beats an AttributeError deep inside a fleet run ("stats" is an
# instance attribute on ServingEngine, so it is checked per-instance via
# isinstance(x, EngineLike) instead)
for _impl in (ServingEngine, EnginePool):
    _missing = [m for m in ("capacity", "load", "has_work",
                            "submit", "step", "pump", "cancel", "saturated",
                            "run_until") if not hasattr(_impl, m)]
    assert not _missing, \
        f"{_impl.__name__} does not satisfy EngineLike: missing {_missing}"
del _impl, _missing

__all__ = ["AutoscalePolicy", "Autoscaler", "ColdStartModel", "EngineLike",
           "EnginePool", "FaultError", "FaultInjector", "FaultPlan",
           "InjectedFault", "JAXExecutor", "Phase", "Request", "RetryPolicy",
           "RuntimeReport", "ServingConfig", "ServingEngine",
           "ServingRuntime", "Trace", "day_cycle"]
