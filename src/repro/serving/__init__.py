"""Real-model serving: slot-batched engines, replicated engine pools,
the Executor adapter and the multi-query fleet runtime."""
from repro.serving.engine import JAXExecutor, Request, ServingEngine
from repro.serving.pool import EnginePool
from repro.serving.runtime import RuntimeReport, ServingRuntime

__all__ = ["EnginePool", "JAXExecutor", "Request", "RuntimeReport",
           "ServingEngine", "ServingRuntime"]
