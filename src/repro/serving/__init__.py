"""Real-model serving: slot-batched engines, replicated engine pools,
the Executor adapter, the multi-query fleet runtime, and deterministic
fault injection.

Failure-semantics contract
--------------------------
The serving stack absorbs failures at three layers; each layer has a
fixed answer to "what retries, what degrades, what surfaces":

* **Subtask attempts** (``core.scheduler.RetryPolicy``): an executor
  exception on ``run``/``submit`` or a per-attempt deadline
  (``timeout_s``) overrun **retries** with capped exponential backoff,
  up to ``max_retries`` times per side. Timed-out attempts are cancelled
  (the KV slot frees) and their sunk cost — tokens already decoded — is
  charged to the per-query and global budgets.
* **Cloud exhaustion** (graceful degradation): a *cloud* subtask out of
  retries **degrades** to the edge executor through the same offload
  bookkeeping the spill path uses, with a fresh attempt budget; its
  ``SubtaskResult`` records ``degraded=True`` and the absorbed
  ``retries``. Only an *edge*-side exhaustion (or
  ``degrade_to_edge=False``) **surfaces** as a ``RuntimeError``.
* **Pool replicas** (``EnginePool``): a replica whose pump step raises is
  marked **dead** — the worker-thread exception is captured at the join,
  never lost — and its in-flight requests **fail over** to the
  least-loaded survivor (restarted from the prompt; generation state
  died with the replica's KV slots). A replica holding work without
  progress for ``suspect_after`` passes turns **suspect**: its work is
  hedged onto healthy replicas and dispatch deprioritizes it until it
  recovers. Only all-replicas-dead (or ``failover=False``) surfaces.

With ``retry=None`` and no faults, every fault path is provably inert:
runs are bit-identical to the pre-fault-tolerance stack (chaos suite:
``tests/test_faults.py``). ``serving.faults`` provides the seeded
``FaultPlan``/``FaultInjector`` chaos harness that exercises all of the
above reproducibly (``launch/serve.py --faults``).
"""
from repro.core.scheduler import RetryPolicy
from repro.serving.engine import JAXExecutor, Request, ServingEngine
from repro.serving.faults import (FaultError, FaultInjector, FaultPlan,
                                  InjectedFault)
from repro.serving.pool import EnginePool
from repro.serving.runtime import RuntimeReport, ServingRuntime

__all__ = ["EnginePool", "FaultError", "FaultInjector", "FaultPlan",
           "InjectedFault", "JAXExecutor", "Request", "RetryPolicy",
           "RuntimeReport", "ServingEngine", "ServingRuntime"]
