"""GSPMD sharding rules for every architecture family.

Parameter rules (DESIGN.md §5): column-parallel in-projections
(P(None,"model")), row-parallel out-projections (P("model",None)),
vocab-sharded embeddings, expert-sharded MoE weights when n_experts
divides the model axis (else tensor-parallel inside experts). Every rule
is divisibility-guarded: a dim that doesn't divide the axis size stays
replicated (GSPMD would reject it otherwise).

Leading stack axes ([n_layers, ...] from lax.scan stacking, [G, m, ...]
for xLSTM groups) are detected by matching the rule to the *trailing*
dims and padding the spec with None on the left.
"""
from __future__ import annotations

from typing import Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.launch.mesh import data_axes, model_axis_size


def _div(n: int, m: int) -> bool:
    return m > 0 and n % m == 0


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
    return tuple(out)


# projection weight classes by the *owning* parameter name
_COL_PARALLEL = {"wq", "wk", "wv", "up", "w_in", "in_proj", "w_gate", "w_up"}
_ROW_PARALLEL = {"wo", "down", "out_proj", "w_down", "out"}


def param_spec(path, leaf, cfg: ModelConfig, mesh: Mesh) -> P:
    ms = model_axis_size(mesh)
    names = _path_names(path)
    owner = names[-2] if len(names) >= 2 else names[-1]
    name = names[-1]
    shape = np.shape(leaf)
    nd = len(shape)

    def pad(spec_tail):
        return P(*([None] * (nd - len(spec_tail)) + list(spec_tail)))

    # --- embeddings ----------------------------------------------------
    if owner in ("embed", "lm_head"):
        V, D = shape[-2:]
        return pad(["model" if _div(V, ms) else None, None])

    # --- MoE expert banks [E, D, F] / [E, F, D] -------------------------
    if "moe" in names and name in ("w_gate", "w_up", "w_down") and nd >= 3:
        E = shape[-3]
        if _div(E, ms):
            return pad(["model", None, None])
        # TP inside experts: shard the F dim
        f_ax = -1 if name != "w_down" else -2
        if _div(shape[f_ax], ms):
            tail = [None, None, None]
            tail[f_ax] = "model"
            return pad(tail)
        return pad([None, None, None])
    if "moe" in names and name == "router":
        return pad([None] * nd)

    # --- sLSTM block-diagonal recurrent weights [H, P, 4P] ---------------
    if name == "r" and nd >= 3:
        return pad(["model" if _div(shape[-3], ms) else None, None, None])

    # --- depthwise conv [K, C] ------------------------------------------
    if name == "conv_w":
        return pad([None, "model" if _div(shape[-1], ms) else None])
    if name == "conv_b":
        return pad(["model" if _div(shape[-1], ms) else None])

    # --- generic dense layers -------------------------------------------
    if name == "w" and nd >= 2:
        d_in, d_out = shape[-2:]
        if owner in _COL_PARALLEL:
            return pad([None, "model" if _div(d_out, ms) else None])
        if owner in _ROW_PARALLEL:
            return pad(["model" if _div(d_in, ms) else None, None])
        if owner == "router":
            return pad([None, None])
        # router MLP / unknown dense: replicate
        return pad([None, None])
    if name == "b":
        if owner in _COL_PARALLEL:
            return pad(["model" if _div(shape[-1], ms) else None])
        return pad([None])

    # norms, gates, scalars (A_log, dt_bias, D, scale, bias, w_gates)
    return P(*([None] * nd))


_FSDP_MIN_ELEMS = 1 << 16   # don't FSDP-shard tiny params (norms, biases)


def _fsdp_augment(spec: P, shape, dsz: int, dp) -> P:
    """§Perf: additionally shard the largest still-replicated dim over the
    data axes (FSDP/ZeRO-3 via GSPMD). Optimizer state mirrors the param
    specs, so fp32 Adam moments shard the same way (ZeRO-1 for free)."""
    import numpy as _np
    if int(_np.prod(shape)) < _FSDP_MIN_ELEMS:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    # pick the largest unsharded, divisible dim
    cands = [(shape[i], i) for i in range(len(shape))
             if entries[i] is None and _div(shape[i], dsz)]
    if not cands:
        return spec
    _, ax = max(cands)
    entries[ax] = dp
    return P(*entries)


def param_shardings(cfg: ModelConfig, params, mesh: Mesh,
                    strategy: str = "tp"):
    """Pytree of NamedSharding matching ``params``.

    strategy: "tp" (baseline tensor parallel, replicated over data axes)
    or "fsdp" (additionally shard params/grads/optimizer state over the
    data axes; §Perf memory optimization).
    """
    import math
    dp = data_axes(mesh)
    dsz = math.prod(mesh.shape[a] for a in dp)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = []
    for path, leaf in flat:
        spec = param_spec(path, leaf, cfg, mesh)
        if strategy == "fsdp":
            spec = _fsdp_augment(spec, np.shape(leaf), dsz, dp)
        specs.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------------------
# activations / inputs
# --------------------------------------------------------------------------

def batch_shardings(cfg: ModelConfig, batch, mesh: Mesh):
    """Training/prefill batch dict: batch dim over the data axes."""
    dp = data_axes(mesh)
    import math
    dsz = math.prod(mesh.shape[a] for a in dp)

    def spec(path, leaf):
        shape = np.shape(leaf)
        tail = [None] * (len(shape) - 1)
        lead = dp if _div(shape[0], dsz) else None
        return NamedSharding(mesh, P(lead, *tail))

    return jax.tree_util.tree_map_with_path(spec, batch)


def cache_spec(path, leaf, *, dsz: int, ms: int, dp) -> P:
    """PartitionSpec for one cache leaf (pure logic; testable without a
    device mesh). Batch over data axes when divisible, otherwise
    (long_500k batch=1) shard the sequence/window dim; head-ish dims go on
    "model" when divisible."""
    shape = np.shape(leaf)
    nd = len(shape)
    names = _path_names(path)
    tail: list = [None] * nd
    # attention caches: [L, B, M, KV, hd] / cross [L, B, enc, KV, hd]
    if names and names[-1] in ("k", "v", "xk", "xv") and nd == 5:
        L, B, Mx, KVh, hd = shape
        tail = [None, None, None, None, None]
        if _div(B, dsz):
            tail[1] = dp
            if _div(KVh, ms):
                tail[3] = "model"
            elif _div(hd, ms):
                tail[4] = "model"
        else:
            # batch=1 long-context: context parallelism over the window
            if _div(Mx, dsz):
                tail[2] = dp
            if _div(KVh, ms):
                tail[3] = "model"
            elif _div(hd, ms):
                tail[4] = "model"
        return P(*tail)
    # recurrent states: find batch axis; shard one big inner dim on model
    b_ax = None
    for ax in range(nd):
        if _div(shape[ax], dsz) and shape[ax] >= dsz and b_ax is None \
                and ax < nd - 1 and shape[ax] <= 4096:
            b_ax = ax
            break
    if b_ax is not None:
        tail[b_ax] = dp
    for ax in range(nd - 1, b_ax if b_ax is not None else -1, -1):
        if ax != b_ax and _div(shape[ax], ms) and shape[ax] >= ms:
            tail[ax] = "model"
            break
    return P(*tail)


def cache_shardings(cfg: ModelConfig, cache, mesh: Mesh):
    dp = data_axes(mesh)
    ms = model_axis_size(mesh)
    import math
    dsz = math.prod(mesh.shape[a] for a in dp)

    def spec(path, leaf):
        return NamedSharding(mesh, cache_spec(path, leaf, dsz=dsz, ms=ms,
                                              dp=dp))

    return jax.tree_util.tree_map_with_path(spec, cache)


def token_shardings(shape_batch: int, mesh: Mesh):
    dp = data_axes(mesh)
    import math
    dsz = math.prod(mesh.shape[a] for a in dp)
    lead = dp if _div(shape_batch, dsz) else None
    return (NamedSharding(mesh, P(lead, None)),   # token [B,1]
            NamedSharding(mesh, P(lead)))          # pos [B]


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
