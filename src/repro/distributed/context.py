"""Ambient mesh context: layers that need explicit SPMD (shard_map MoE)
read the mesh here; drivers (dryrun/train/serve) set it around tracing."""
from __future__ import annotations

from contextlib import contextmanager

_MESH = None


def set_mesh(mesh) -> None:
    global _MESH
    _MESH = mesh


def get_mesh():
    return _MESH


@contextmanager
def mesh_context(mesh):
    old = get_mesh()
    set_mesh(mesh)
    try:
        yield
    finally:
        set_mesh(old)
