"""Task-decomposition DAG: validation, bounded repair, scheduling order.

Implements Definition C.1/C.2 and the validation-and-repair procedure of
HybridFlow App. C: a plan is valid iff it is (1) acyclic, (2) rooted at a
unique EXPLAIN node with no prerequisites, (3) fully reachable from the
root, (4) has exactly one GENERATE sink, (5) has at most n_max nodes, and
(6) is dependency-consistent (Req(t_i) ⊆ ∪_{j∈P_i} Prod(t_j)). Invalid
plans get at most R_max deterministic repair rounds; if still invalid the
plan falls back to a sequential chain (paper: R_max=2, n_max=7).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Set, Tuple

N_MAX = 7
R_MAX = 2

ROLES = ("EXPLAIN", "ANALYZE", "GENERATE")


@dataclass(frozen=True)
class Node:
    """One subtask in a plan DAG (Definition C.1)."""

    sid: int
    desc: str
    role: str                          # EXPLAIN | ANALYZE | GENERATE
    deps: Tuple[int, ...] = ()
    requires: Tuple[str, ...] = ()     # Req(t_i) symbols
    produces: Tuple[str, ...] = ()     # Prod(t_i) symbols
    confidence: Dict[int, float] = field(default_factory=dict)  # per-edge


@dataclass(frozen=True)
class PlanDAG:
    nodes: Tuple[Node, ...]

    @property
    def n(self) -> int:
        return len(self.nodes)

    def node(self, sid: int) -> Node:
        for nd in self.nodes:
            if nd.sid == sid:
                return nd
        raise KeyError(sid)

    @property
    def sids(self) -> List[int]:
        return [nd.sid for nd in self.nodes]

    def children(self, sid: int) -> List[int]:
        return [nd.sid for nd in self.nodes if sid in nd.deps]


@dataclass(frozen=True)
class ValidationResult:
    ok: bool
    errors: Tuple[str, ...] = ()


def validate(dag: PlanDAG, n_max: int = N_MAX) -> ValidationResult:
    errs: List[str] = []
    sids = dag.sids
    if len(set(sids)) != len(sids):
        errs.append("duplicate-ids")
    sid_set = set(sids)
    for nd in dag.nodes:
        for d in nd.deps:
            if d not in sid_set:
                errs.append(f"dangling-edge:{nd.sid}->{d}")
            if d == nd.sid:
                errs.append(f"self-edge:{nd.sid}")
    if dag.n > n_max:
        errs.append("too-many-nodes")
    if dag.n == 0:
        return ValidationResult(False, ("empty",))
    # acyclicity via Kahn
    order = topological_order(dag)
    if order is None:
        errs.append("cycle")
    # rooted plan: unique EXPLAIN node with no deps
    roots = [nd for nd in dag.nodes if not nd.deps]
    explain_roots = [nd for nd in roots if nd.role == "EXPLAIN"]
    if len(explain_roots) != 1 or len(roots) != 1:
        errs.append("not-rooted")
    # reachability from root
    elif order is not None:
        root = explain_roots[0].sid
        reach = {root}
        for sid in order:
            if sid == root:
                continue
            if any(d in reach for d in dag.node(sid).deps):
                reach.add(sid)
        if reach != sid_set:
            errs.append("unreachable")
    # GENERATE sinks: exactly one, and GENERATE nodes must be sinks
    gens = [nd for nd in dag.nodes if nd.role == "GENERATE"]
    if len(gens) != 1:
        errs.append("generate-count")
    for nd in gens:
        if dag.children(nd.sid):
            errs.append("generate-not-sink")
    # dependency consistency: Req ⊆ ∪ Prod(parents)
    for nd in dag.nodes:
        avail: Set[str] = set()
        for d in nd.deps:
            if d in sid_set:
                avail |= set(dag.node(d).produces)
        if not set(nd.requires) <= avail:
            errs.append(f"req-unmet:{nd.sid}")
    return ValidationResult(not errs, tuple(errs))


def topological_order(dag: PlanDAG) -> Optional[List[int]]:
    """Kahn's algorithm; None if cyclic. Stable (ascending sid) tiebreak."""
    sid_set = set(dag.sids)
    indeg = {nd.sid: sum(1 for d in nd.deps if d in sid_set) for nd in dag.nodes}
    ready = sorted(s for s, d in indeg.items() if d == 0)
    out: List[int] = []
    while ready:
        s = ready.pop(0)
        out.append(s)
        for c in dag.children(s):
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(c)
                ready.sort()
    return out if len(out) == len(dag.nodes) else None


def critical_path_length(dag: PlanDAG) -> int:
    """L_crit — longest chain (#nodes) through the DAG (Table 7 R_comp)."""
    order = topological_order(dag)
    if order is None:
        return dag.n
    depth = {}
    for sid in order:
        nd = dag.node(sid)
        depth[sid] = 1 + max((depth[d] for d in nd.deps if d in depth), default=0)
    return max(depth.values(), default=0)


def compression_ratio(dag: PlanDAG) -> float:
    """R_comp = (n - L_crit) / n   (paper Eq. 28)."""
    if dag.n == 0:
        return 0.0
    return (dag.n - critical_path_length(dag)) / dag.n


def chain_fallback(dag: PlanDAG) -> PlanDAG:
    """Sequential chain with canonical roles (the paper's fallback)."""
    nodes = []
    n = dag.n
    for i, nd in enumerate(sorted(dag.nodes, key=lambda x: x.sid)):
        role = "EXPLAIN" if i == 0 else ("GENERATE" if i == n - 1 else "ANALYZE")
        deps = (nodes[-1].sid,) if nodes else ()
        req = nodes[-1].produces if nodes else ()
        nodes.append(replace(nd, role=role, deps=deps, requires=req,
                             produces=(f"r{nd.sid}",)))
    return PlanDAG(tuple(nodes))


def _break_cycles(dag: PlanDAG) -> PlanDAG:
    """Remove the lowest-confidence edge of each cycle found (App. C (ii))."""
    nodes = {nd.sid: nd for nd in dag.nodes}
    # iterate: while cyclic, find a cycle by DFS and cut its weakest edge
    for _ in range(dag.n * dag.n + 1):
        d = PlanDAG(tuple(nodes.values()))
        if topological_order(d) is not None:
            return d
        cycle = _find_cycle(d)
        if not cycle:
            return d
        # edges along the cycle: (dep -> node) pairs
        edges = [(cycle[i], cycle[(i + 1) % len(cycle)]) for i in range(len(cycle))]
        def conf(e):
            dep, s = e
            return nodes[s].confidence.get(dep, 0.5), -dep, -s  # deterministic
        dep, s = min(edges, key=conf)
        nd = nodes[s]
        nodes[s] = replace(nd, deps=tuple(x for x in nd.deps if x != dep))
    return PlanDAG(tuple(nodes.values()))


def _find_cycle(dag: PlanDAG) -> List[int]:
    color = {s: 0 for s in dag.sids}
    stack: List[int] = []

    def dfs(s) -> Optional[List[int]]:
        color[s] = 1
        stack.append(s)
        for c in dag.children(s):
            if color[c] == 1:
                i = stack.index(c)
                return stack[i:]
            if color[c] == 0:
                r = dfs(c)
                if r:
                    return r
        color[s] = 2
        stack.pop()
        return None

    for s in sorted(color):
        if color[s] == 0:
            r = dfs(s)
            if r:
                return r
    return []


def repair(dag: PlanDAG, *, n_max: int = N_MAX, r_max: int = R_MAX
           ) -> Tuple[PlanDAG, str]:
    """Bounded deterministic repair (App. C). Returns (dag, status) with
    status ∈ {valid, repaired, fallback}."""
    if validate(dag, n_max).ok:
        return dag, "valid"
    cur = dag
    for _ in range(r_max):
        cur = _repair_round(cur, n_max)
        if validate(cur, n_max).ok:
            return cur, "repaired"
    return chain_fallback(dag), "fallback"


def _repair_round(dag: PlanDAG, n_max: int) -> PlanDAG:
    nodes = {nd.sid: nd for nd in dag.nodes}
    sid_set = set(nodes)
    # (o) drop self/dangling edges; dedupe ids handled by dict construction
    for s, nd in list(nodes.items()):
        deps = tuple(d for d in nd.deps if d in sid_set and d != s)
        if deps != nd.deps:
            nodes[s] = replace(nd, deps=deps)
    # (i) remove ill-typed edges (dependency-consistency violations):
    # an edge j->i whose Prod(j) contributes nothing to Req(i) *and* whose
    # removal doesn't orphan i is dropped only when the req-check fails
    for s, nd in list(nodes.items()):
        if not nd.requires:
            continue
        avail = {sym for d in nd.deps for sym in nodes[d].produces}
        if not set(nd.requires) <= avail:
            # relax requirements we cannot satisfy (planner hallucinated them)
            nodes[s] = replace(nd, requires=tuple(r for r in nd.requires
                                                  if r in avail))
    # (ii) break cycles at lowest-confidence edges
    d = _break_cycles(PlanDAG(tuple(nodes.values())))
    nodes = {nd.sid: nd for nd in d.nodes}
    # size constraint: merge trailing extra nodes into the last n_max
    if len(nodes) > n_max:
        keep = sorted(nodes)[:n_max]
        kept = set(keep)
        for s in list(nodes):
            if s not in kept:
                del nodes[s]
        for s, nd in list(nodes.items()):
            nodes[s] = replace(nd, deps=tuple(x for x in nd.deps if x in kept))
    # (iii) enforce rootedness/reachability: unique EXPLAIN root, orphans
    # attach to it
    sids = sorted(nodes)
    root = None
    for s in sids:
        if nodes[s].role == "EXPLAIN" and not nodes[s].deps:
            root = s
            break
    if root is None:
        root = sids[0]
        nodes[root] = replace(nodes[root], role="EXPLAIN", deps=(), requires=())
    for s in sids:
        if s == root:
            # root must have no deps
            if nodes[s].deps:
                nodes[s] = replace(nodes[s], deps=(), requires=())
            continue
        if nodes[s].role == "EXPLAIN":
            nodes[s] = replace(nodes[s], role="ANALYZE")
        if not nodes[s].deps:
            nodes[s] = replace(nodes[s], deps=(root,))
    # reachability: attach any unreachable node to the root
    d = PlanDAG(tuple(nodes[s] for s in sorted(nodes)))
    order = topological_order(d)
    if order is not None:
        reach = {root}
        for sid in order:
            if sid != root and any(x in reach for x in nodes[sid].deps):
                reach.add(sid)
        for s in sids:
            if s not in reach:
                nodes[s] = replace(nodes[s], deps=tuple(set(nodes[s].deps) | {root}))
    # (iv) exactly one GENERATE sink: demote non-sink GENERATEs, promote the
    # last sink if none
    d = PlanDAG(tuple(nodes[s] for s in sorted(nodes)))
    gens = [s for s in sorted(nodes) if nodes[s].role == "GENERATE"]
    sinks = [s for s in sorted(nodes) if not d.children(s)]
    for s in gens:
        if d.children(s) or s != gens[-1]:
            nodes[s] = replace(nodes[s], role="ANALYZE")
    gens = [s for s in sorted(nodes) if nodes[s].role == "GENERATE"]
    if not gens and sinks:
        last = sinks[-1]
        if last == root and len(nodes) > 1:
            last = sorted(nodes)[-1]
        if last != root:
            nodes[last] = replace(nodes[last], role="GENERATE")
    # make the GENERATE node a sink by dropping out-edges
    gens = [s for s in sorted(nodes) if nodes[s].role == "GENERATE"]
    if gens:
        g = gens[0]
        for s, nd in list(nodes.items()):
            if g in nd.deps:
                nodes[s] = replace(nd, deps=tuple(x for x in nd.deps if x != g))
    return PlanDAG(tuple(nodes[s] for s in sorted(nodes)))
