"""EAG planner: XML plan generation + robust parsing (paper §3.2, Fig. 6).

The planner model M_P emits an XML plan::

    <Plan>
      <Step ID="1" Task="Explain: ..." Rely=""/>
      <Step ID="2" Task="Analyze: ..." Rely="1"/>
      <Step ID="6" Task="Generate: ..." Rely="2,3" Confidence="2:0.9,3:0.4"/>
    </Plan>

``parse_plan`` converts that to a PlanDAG; ``SyntheticPlanner`` plays the
role of the edge-deployed Llama3.2-3B: it recovers the query's latent
ground-truth decomposition with controlled corruption rates so the
validity/repair statistics of Table 5 are reproducible (valid ≈76%,
repaired ≈14%, fallback ≈10% on the GPQA stand-in). Any JAX LM can be
substituted via the Planner protocol (``plan_xml(query_text) -> str``).
"""
from __future__ import annotations

import re
import xml.etree.ElementTree as ET
from dataclasses import dataclass, replace
from typing import Dict, List, Optional, Protocol, Tuple


from repro.core.dag import Node, PlanDAG, repair, N_MAX, R_MAX
from repro.data.tasks import Query, _rng


class Planner(Protocol):
    def plan_xml(self, query_text: str) -> str: ...


# --------------------------------------------------------------------------
# XML <-> PlanDAG
# --------------------------------------------------------------------------

def plan_to_xml(dag: PlanDAG) -> str:
    lines = ["<Plan>"]
    for nd in dag.nodes:
        rely = ",".join(str(d) for d in nd.deps)
        conf = ",".join(f"{d}:{c:.2f}" for d, c in sorted(nd.confidence.items()))
        role_word = nd.role.capitalize()
        desc = nd.desc
        if not re.match(r"^(Explain|Analyze|Generate):", desc):
            desc = f"{role_word}: {desc}"
        attrs = f'ID="{nd.sid + 1}" Task="{_esc(desc)}" Rely="{rely and _shift(rely)}"'
        if conf:
            attrs += f' Confidence="{_shift_conf(conf)}"'
        lines.append(f'  <Step {attrs}/>')
    lines.append("</Plan>")
    return "\n".join(lines)


def _esc(s: str) -> str:
    return (s.replace("&", "&amp;").replace('"', "&quot;")
            .replace("<", "&lt;").replace(">", "&gt;"))


def _shift(rely: str) -> str:
    return ",".join(str(int(x) + 1) for x in rely.split(",") if x.strip())


def _shift_conf(conf: str) -> str:
    out = []
    for part in conf.split(","):
        d, c = part.split(":")
        out.append(f"{int(d) + 1}:{c}")
    return ",".join(out)


_ROLE_RE = re.compile(r"^\s*(explain|analyze|analyse|generate)\s*:", re.I)


def parse_plan(xml_text: str) -> PlanDAG:
    """Tolerant XML plan parser. Raises ValueError on unusable input."""
    # strip junk around the <Plan> element (LLMs add prose)
    m = re.search(r"<Plan>.*</Plan>", xml_text, re.S | re.I)
    if m:
        xml_text = m.group(0)
    try:
        root = ET.fromstring(xml_text)
    except ET.ParseError:
        # last resort: regex-extract Step tags
        return _regex_parse(xml_text)
    nodes: List[Node] = []
    for step in root.iter():
        if step.tag.lower() != "step":
            continue
        sid = _to_int(step.get("ID") or step.get("id"))
        if sid is None:
            continue
        task = step.get("Task") or step.get("task") or ""
        rely = step.get("Rely") or step.get("rely") or ""
        deps = tuple(d - 1 for d in _parse_ids(rely))
        conf = _parse_conf(step.get("Confidence") or "")
        role = _infer_role(task)
        nodes.append(Node(sid - 1, task, role, deps,
                          requires=tuple(f"r{d}" for d in deps),
                          produces=(f"r{sid - 1}",),
                          confidence=conf))
    if not nodes:
        raise ValueError("no steps parsed")
    return PlanDAG(tuple(nodes))


def _regex_parse(text: str) -> PlanDAG:
    nodes = []
    for m in re.finditer(
            r'<Step\s+ID="(\d+)"\s+Task="(.*?)"\s+Rely="([\d,\s]*)"', text, re.S):
        sid = int(m.group(1)) - 1
        deps = tuple(d - 1 for d in _parse_ids(m.group(3)))
        nodes.append(Node(sid, m.group(2), _infer_role(m.group(2)), deps,
                          requires=tuple(f"r{d}" for d in deps),
                          produces=(f"r{sid}",)))
    if not nodes:
        raise ValueError("unparseable plan")
    return PlanDAG(tuple(nodes))


def _to_int(s) -> Optional[int]:
    try:
        return int(str(s).strip())
    except (TypeError, ValueError):
        return None


def _parse_ids(s: str) -> List[int]:
    out = []
    for part in str(s).replace(";", ",").split(","):
        v = _to_int(part)
        if v is not None:
            out.append(v)
    return out


def _parse_conf(s: str) -> Dict[int, float]:
    out: Dict[int, float] = {}
    for part in s.split(","):
        if ":" in part:
            d, c = part.split(":", 1)
            di, = (_to_int(d),)
            try:
                out[di - 1] = float(c)
            except (TypeError, ValueError):
                pass
    return out


def _infer_role(task: str) -> str:
    m = _ROLE_RE.match(task or "")
    if not m:
        return "ANALYZE"
    w = m.group(1).lower()
    return {"explain": "EXPLAIN", "analyze": "ANALYZE",
            "analyse": "ANALYZE", "generate": "GENERATE"}[w]


# --------------------------------------------------------------------------
# synthetic planner (controlled corruption, Table 5 statistics)
# --------------------------------------------------------------------------

@dataclass
class CorruptionRates:
    """Probability of each defect class in the raw plan."""

    extra_cycle: float = 0.05       # add a back-edge (cycle)
    drop_edge: float = 0.06         # orphan a node
    double_generate: float = 0.05   # second GENERATE node
    bad_requires: float = 0.06      # hallucinated Req symbol
    oversize: float = 0.04          # splits a node past n_max
    garble_xml: float = 0.035       # truncated XML (regex-recoverable)
    severe_garble: float = 0.10     # unusable output -> chain fallback


class SyntheticPlanner:
    """Recovers the query's latent DAG with seeded corruption.

    plan(query) -> (PlanDAG, status) runs the full parse+validate+repair
    pipeline exactly as a real deployment would.
    """

    def __init__(self, rates: Optional[CorruptionRates] = None, seed: int = 0,
                 n_max: int = N_MAX, r_max: int = R_MAX):
        self.rates = rates or CorruptionRates()
        self.seed = seed
        self.n_max = n_max
        self.r_max = r_max

    def true_dag(self, query: Query) -> PlanDAG:
        nodes = [Node(st.sid, st.desc, st.role, st.deps,
                      requires=st.requires, produces=st.produces,
                      confidence={d: 0.5 + 0.5 * (1 - st.difficulty)
                                  for d in st.deps})
                 for st in query.subtasks]
        return PlanDAG(tuple(nodes))

    def plan_xml(self, query: Query) -> str:
        dag = self.true_dag(query)
        rng = _rng("planner", self.seed, query.qid)
        r = self.rates
        nodes = list(dag.nodes)
        if rng.random() < r.drop_edge and len(nodes) > 2:
            i = int(rng.integers(1, len(nodes)))
            nodes[i] = replace(nodes[i], deps=(), requires=())
        if rng.random() < r.extra_cycle and len(nodes) > 2:
            i = int(rng.integers(0, len(nodes) - 1))
            j = int(rng.integers(i + 1, len(nodes)))
            # back-edge j -> i creates a cycle if i depends (transitively) on j
            ni = nodes[i]
            nodes[i] = replace(ni, deps=tuple(set(ni.deps) | {nodes[j].sid}),
                               confidence={**ni.confidence, nodes[j].sid: 0.1})
        if rng.random() < r.double_generate and len(nodes) > 2:
            i = int(rng.integers(1, len(nodes) - 1))
            nodes[i] = replace(nodes[i], role="GENERATE")
        if rng.random() < r.bad_requires:
            i = int(rng.integers(0, len(nodes)))
            nodes[i] = replace(nodes[i],
                               requires=nodes[i].requires + ("r_phantom",))
        if rng.random() < r.oversize:
            extra_id = max(nd.sid for nd in nodes) + 1
            for k in range(self.n_max + 1 - len(nodes)):
                nodes.append(Node(extra_id + k, f"Analyze: filler {k}",
                                  "ANALYZE", (0,), requires=("r0",),
                                  produces=(f"r{extra_id + k}",)))
        xml = plan_to_xml(PlanDAG(tuple(nodes)))
        if rng.random() < r.severe_garble:
            # planner rambles without a parseable plan (chain fallback)
            return "I think we should first consider the problem. Step one..."
        if rng.random() < r.garble_xml:
            xml = xml.replace("</Plan>", "")  # truncated output
        return xml

    def plan(self, query: Query) -> Tuple[PlanDAG, str]:
        """Full pipeline: emit XML, parse, validate+repair (chain fallback
        also triggers on parse failure)."""
        xml = self.plan_xml(query)
        try:
            dag = parse_plan(xml)
        except ValueError:
            from repro.core.dag import chain_fallback
            return chain_fallback(self.true_dag(query)), "fallback"
        fixed, status = repair(dag, n_max=self.n_max, r_max=self.r_max)
        return fixed, status


def decompose(query: Query, planner: Optional[SyntheticPlanner] = None
              ) -> Tuple[PlanDAG, str]:
    """(T, E) = Decompose(Q; M_P) with validation/repair (Algorithm 1, Stage 1)."""
    planner = planner or SyntheticPlanner()
    return planner.plan(query)
