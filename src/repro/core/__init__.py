"""HybridFlow core — the paper's primary contribution.

Dependency-aware DAG decomposition (dag, planner), utility-based
budget-adaptive routing (utility, router, dual, bandit), dependency-
triggered scheduling (scheduler), offline credit assignment (profiler),
and the end-to-end pipeline with all paper baselines (hybridflow).
"""
__all__ = [
    "PlanDAG", "Node", "validate", "repair", "chain_fallback",
    "topological_order", "critical_path_length", "compression_ratio",
    "SyntheticPlanner", "parse_plan", "plan_to_xml", "decompose",
    "Router", "RouterConfig", "train_router",
    "FleetScheduler", "QueryResult", "Schedule", "SubtaskResult",
    "run_query",
    "DualController", "TwoBudgetThreshold", "LinUCBCalibrator",
    "Pipeline", "HybridFlowPolicy", "MethodOutput",
    "train_default_router", "profile_queries",
]

from repro.core.dag import (PlanDAG, Node, validate, repair, chain_fallback,
                            topological_order, critical_path_length,
                            compression_ratio)
from repro.core.planner import (SyntheticPlanner, parse_plan, plan_to_xml,
                                decompose)
from repro.core.router import Router, RouterConfig, train_router
from repro.core.scheduler import (FleetScheduler, QueryResult, Schedule,
                                  SubtaskResult, run_query)
from repro.core.dual import DualController, TwoBudgetThreshold
from repro.core.bandit import LinUCBCalibrator
from repro.core.hybridflow import Pipeline, HybridFlowPolicy, MethodOutput
from repro.core.profiler import train_default_router, profile_queries
