"""Utility model + knapsack formulation (paper §3.1, App. B).

  c_i = ½·Δl_i/l_max + ½·Δk_i/k_max           (Eq. 1 / Eq. 24)
  u_i = clip(Δq_i / (c_i + ε), 0, 1)           (Eq. 2 / Eq. 25)

plus the 0-1 knapsack DP oracle (App. B.1 — the upper bound HybridFlow's
learned router approximates) and the Lagrangian threshold policy
r*_i(λ) = 1[Δq_i/c_i > λ] (Eq. 6 / Eq. 18-19).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence, Tuple

import numpy as np

EPS = 1e-4
# App. C Eq. 24 normalization scales: 10 s latency, $0.02 API cost
L_MAX_SUB = 10.0
K_MAX_SUB = 0.02


def normalized_cost(dl: float, dk: float, *, l_max: float = L_MAX_SUB,
                    k_max: float = K_MAX_SUB) -> float:
    """Eq. 1/24 (clipped to [0,1])."""
    return float(np.clip(0.5 * dl / l_max + 0.5 * dk / k_max, 0.0, 1.0))


def utility(dq: float, c: float, *, eps: float = EPS) -> float:
    """Eq. 2/25."""
    return float(np.clip(dq / (c + eps), 0.0, 1.0))


def lagrangian_policy(dq: Sequence[float], c: Sequence[float], lam: float
                      ) -> np.ndarray:
    """r*_i(λ) = 1[Δq_i - λ c_i > 0] (Eq. 6)."""
    dq = np.asarray(dq, float)
    c = np.asarray(c, float)
    return (dq - lam * c > 0).astype(np.int64)


def knapsack_oracle(dq: Sequence[float], c: Sequence[float], budget: float,
                    *, grid: int = 1000) -> Tuple[np.ndarray, float]:
    """0-1 knapsack via DP on a discretized weight grid (App. B.1).

    Returns (allocation r, total value). Weights are FLOOR-discretized, so
    every continuously-feasible allocation stays feasible and the DP value
    UPPER-bounds the true optimum (the oracle's role in the paper: the
    bound the learned router approximates). The returned allocation may
    overshoot the budget by at most n/grid.
    """
    dq = np.asarray(dq, float)
    c = np.asarray(c, float)
    n = len(dq)
    W = int(np.floor(budget * grid + 1e-9))
    w = np.minimum(np.floor(c * grid + 1e-9).astype(int), grid * 10)
    w = np.maximum(w, 0)
    # value-maximizing DP; dp[j] = best value with weight <= j
    dp = np.zeros(W + 1)
    choice = np.zeros((n, W + 1), dtype=bool)
    for i in range(n):
        if dq[i] <= 0:
            continue
        wi = w[i]
        if wi > W:
            continue
        cand = np.concatenate([np.zeros(wi), dp[:W + 1 - wi] + dq[i]])
        take = cand > dp
        choice[i] = take
        dp = np.where(take, cand, dp)
    # backtrack
    r = np.zeros(n, dtype=np.int64)
    j = W
    for i in range(n - 1, -1, -1):
        if choice[i, j]:
            r[i] = 1
            j -= w[i]
    return r, float(np.sum(dq * r))


def greedy_ratio(dq: Sequence[float], c: Sequence[float], budget: float
                 ) -> np.ndarray:
    """Greedy benefit-cost ratio baseline (the relaxation's integral greedy)."""
    dq = np.asarray(dq, float)
    c = np.asarray(c, float)
    order = np.argsort(-dq / (c + EPS))
    r = np.zeros(len(dq), dtype=np.int64)
    used = 0.0
    for i in order:
        if dq[i] > 0 and used + c[i] <= budget:
            r[i] = 1
            used += c[i]
    return r


@dataclass(frozen=True)
class UnifiedMetric:
    """Paper Table 3/6 unified (normalized cost c, utility u) per method.

    Reverse-engineered from the paper's own numbers (Cloud row: lat 18.26,
    k 0.0185, edge-only lat 11.99 -> c = ½·0.0185/0.02 + ½·6.27/10 = 0.776
    and u = (57.28-25.54)/100 / 0.776 = 0.409, matching Table 3 exactly):
    both Δl and Δk are measured *relative to the Edge-only baseline*, with
    the per-subtask scales of Eq. 24 (10 s, $0.02).
    """

    accuracy: float
    latency: float
    api_cost: float

    def normalized_cost(self, *, edge_latency: float, edge_cost: float = 0.0,
                        l_scale: float = L_MAX_SUB,
                        k_scale: float = K_MAX_SUB) -> float:
        dl = self.latency - edge_latency
        dk = self.api_cost - edge_cost
        return float(np.clip(0.5 * dl / l_scale + 0.5 * dk / k_scale,
                             0.0, 1.0))

    def utility(self, edge_accuracy: float, edge_latency: float,
                edge_cost: float = 0.0) -> float:
        """Accuracy gain over edge-only per unit normalized cost."""
        dq = self.accuracy - edge_accuracy
        c = self.normalized_cost(edge_latency=edge_latency, edge_cost=edge_cost)
        return float(np.clip(dq / (c + EPS), 0.0, 1.0))
