"""Contextual-bandit calibration head (paper Eq. 13-14).

Calibrated utility  ũ_i = clip(α û_i + β + wᵀ s_i, 0, 1)  with (α, β, w)
updated online from *partial feedback*: the reward R_i = Δq_i − λ_t c_i is
observed only when the subtask was offloaded (r_i = 1). We use LinUCB on
the feature x = [û_i, 1, s_i]: the point estimate supplies the calibrated
utility, the UCB bonus drives exploration of offloading.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


@dataclass
class LinUCBCalibrator:
    dim: int                      # len(s_i) context features
    alpha_ucb: float = 0.5        # exploration width
    ridge: float = 1.0
    A: np.ndarray = field(init=False)
    b: np.ndarray = field(init=False)

    def __post_init__(self):
        d = self.dim + 2          # [û, 1, s]
        self.A = np.eye(d) * self.ridge
        # warm-start prior θ0 = e1 (α=1, β=w=0): ũ == û until evidence
        # accumulates, so enabling calibration never degrades a well-
        # calibrated router from step 0
        self.b = np.zeros(d)
        self.b[0] = self.ridge

    def _x(self, u_hat: float, s: Sequence[float]) -> np.ndarray:
        return np.concatenate([[u_hat, 1.0], np.asarray(s, float)])

    @property
    def theta(self) -> np.ndarray:
        return np.linalg.solve(self.A, self.b)

    def calibrated(self, u_hat: float, s: Sequence[float]) -> float:
        """ũ point estimate (Eq. 13): α û + β + wᵀ s."""
        x = self._x(u_hat, s)
        return float(np.clip(self.theta @ x, 0.0, 1.0))

    def ucb(self, u_hat: float, s: Sequence[float]) -> float:
        """Optimistic utility used for the offload decision."""
        x = self._x(u_hat, s)
        width = np.sqrt(x @ np.linalg.solve(self.A, x))
        return float(np.clip(self.theta @ x + self.alpha_ucb * width, 0.0, 1.0))

    def update(self, u_hat: float, s: Sequence[float], reward: float) -> None:
        """Partial feedback: call only when the subtask was offloaded."""
        x = self._x(u_hat, s)
        self.A += np.outer(x, x)
        self.b += reward * x


def reward(dq: float, lam: float, c: float) -> float:
    """R_i = Δq_i − λ_t c_i (Eq. 14)."""
    return dq - lam * c
