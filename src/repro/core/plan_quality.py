"""Intrinsic plan-quality evaluation (paper App. D / Fig. 5).

Five dimensions scored in [0,1] against the query's latent ground-truth
decomposition — the paper's "dual-faceted evaluation framework" intrinsic
half (the extrinsic half is the end-to-end accuracy the benchmark tables
already measure):

  soundness    — node coverage of the ground-truth subtasks
  dependency   — F1 of the plan's edge set vs the true edges
  clarity      — executable descriptions (role-tagged, non-empty, bounded)
  attributes   — difficulty-tier signal preserved in the descriptions
  efficiency   — no redundant/filler nodes beyond the true decomposition
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Sequence

import numpy as np

from repro.core.dag import PlanDAG
from repro.data.tasks import Query, _TIER_WORDS


@dataclass(frozen=True)
class PlanQuality:
    soundness: float
    dependency: float
    clarity: float
    attributes: float
    efficiency: float

    @property
    def overall(self) -> float:
        return float(np.mean([self.soundness, self.dependency, self.clarity,
                              self.attributes, self.efficiency]))


def _edge_set(dag: PlanDAG):
    return {(d, nd.sid) for nd in dag.nodes for d in nd.deps}


def score_plan(query: Query, dag: PlanDAG) -> PlanQuality:
    true_ids = {st.sid for st in query.subtasks}
    plan_ids = set(dag.sids)

    # soundness: fraction of true subtasks present in the plan
    soundness = len(true_ids & plan_ids) / max(len(true_ids), 1)

    # dependency structure: edge F1 vs ground truth
    true_edges = {(d, st.sid) for st in query.subtasks for d in st.deps}
    plan_edges = _edge_set(dag)
    tp = len(true_edges & plan_edges)
    prec = tp / max(len(plan_edges), 1)
    rec = tp / max(len(true_edges), 1)
    dependency = 2 * prec * rec / max(prec + rec, 1e-9)

    # clarity: role-tagged, non-trivial, bounded descriptions
    def clear(nd):
        d = nd.desc.strip()
        return (len(d) >= 10 and len(d) <= 400
                and nd.role in ("EXPLAIN", "ANALYZE", "GENERATE"))
    clarity = float(np.mean([clear(nd) for nd in dag.nodes]))

    # attribute accuracy: difficulty-tier words in the plan match the
    # ground-truth subtask's tier (the router's input signal)
    tier_of = {}
    for st in query.subtasks:
        tier_of[st.sid] = min(int(st.difficulty * len(_TIER_WORDS)),
                              len(_TIER_WORDS) - 1)
    hits, total = 0, 0
    for nd in dag.nodes:
        if nd.sid not in tier_of:
            continue
        total += 1
        words = set(nd.desc.lower().split())
        if words & set(_TIER_WORDS[tier_of[nd.sid]]):
            hits += 1
    attributes = hits / max(total, 1)

    # efficiency: penalize nodes with no ground-truth counterpart
    extra = len(plan_ids - true_ids)
    efficiency = max(0.0, 1.0 - extra / max(len(plan_ids), 1))

    return PlanQuality(soundness, dependency, clarity, attributes, efficiency)


def mean_quality(queries: Sequence[Query], planner) -> Dict[str, float]:
    dims = {k: [] for k in ("soundness", "dependency", "clarity",
                            "attributes", "efficiency", "overall")}
    for q in queries:
        dag, _ = planner.plan(q)
        pq = score_plan(q, dag)
        for k in dims:
            dims[k].append(getattr(pq, k) if k != "overall" else pq.overall)
    return {k: float(np.mean(v)) for k, v in dims.items()}
