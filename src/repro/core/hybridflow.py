"""HybridFlow end-to-end pipeline + every baseline from the paper's tables.

Methods (Tables 1-3):
  direct(model)        — single prompt, no decomposition
  cot(model)           — sequential decomposed execution on one model
  sot(model)           — dependency-ignoring parallel execution (SoT)
  pasta(model)         — partial dependency respect (async decoding proxy)
  hybridllm            — query-level edge/cloud routing, sequential
  dot                  — per-step routing, sequential (DoT)
  hybridflow_chain     — our router, DAG parallelism disabled (ablation)
  hybridflow           — full system (Algorithm 1)
  random / fixed(τ0)   — Table 3 ablation arms
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.dag import PlanDAG, chain_fallback
from repro.core.planner import SyntheticPlanner
from repro.core.scheduler import (QueryResult, RoutingPolicy, SubtaskResult,
                                  WorldModelExecutor, run_query,
                                  run_parallel_ignore_deps, Schedule)
from repro.core.dual import TwoBudgetThreshold
from repro.core.bandit import LinUCBCalibrator
from repro.core.router import Router
from repro.data.tasks import Query, WorldModel, _rng


# --------------------------------------------------------------------------
# routing policies
# --------------------------------------------------------------------------

class _BasePolicy:
    def observe(self, query, node, r, result, ctx):  # default no-op
        pass


class StaticPolicy(_BasePolicy):
    def __init__(self, r: int):
        self.r = r

    def decide(self, query, node, ctx):
        return self.r, {}


class RandomPolicy(_BasePolicy):
    def __init__(self, p: float = 0.5, seed: int = 0):
        self.p = p
        self.seed = seed

    def decide(self, query, node, ctx):
        u = float(_rng("randpolicy", self.seed, query.qid, node.sid).random())
        return int(u < self.p), {}


class FixedThresholdPolicy(_BasePolicy):
    """û_i > τ0 with no budget adaptation (Table 6 sweep arm)."""

    def __init__(self, router: Router, tau0: float):
        self.router = router
        self.tau0 = tau0

    def decide(self, query, node, ctx):
        u_hat = self.router.predict_one(node.desc, 0.0)
        ctx.tau_trace.append(self.tau0)
        return int(u_hat > self.tau0), {"u_hat": u_hat}


class QueryLevelPolicy(_BasePolicy):
    """HybridLLM-style: one routing decision for the whole query."""

    def __init__(self, router: Router, tau: float = 0.45):
        self.router = router
        self.tau = tau
        self._cache: Dict[str, int] = {}

    def decide(self, query, node, ctx):
        if query.qid not in self._cache:
            descs = [st.desc for st in query.subtasks]
            mean_u = float(np.mean(self.router.predict(descs, 0.0)))
            self._cache[query.qid] = int(mean_u > self.tau)
        return self._cache[query.qid], {}


class KnapsackPolicy(_BasePolicy):
    """Beyond-paper: per-query 0-1 knapsack allocation on PREDICTED
    utilities (App. B's DP oracle, run on û instead of the unobservable
    true Δq). Solves the whole query's allocation once when its first
    subtask is routed — a batch-planning upper baseline for the online
    threshold policy (no adaptation to realized spend)."""

    def __init__(self, router: Router, budget: float = 0.5):
        self.router = router
        self.budget = budget
        self._alloc: Dict[str, Dict[int, int]] = {}

    def _solve(self, query: Query) -> Dict[int, int]:
        from repro.core.utility import knapsack_oracle, normalized_cost
        from repro.data.tasks import EDGE_PROFILE, CLOUD_PROFILE
        descs = [st.desc for st in query.subtasks]
        u_hat = self.router.predict(descs, 0.0)
        cs = []
        for st in query.subtasks:
            dl = (CLOUD_PROFILE.latency(st.tok_in, st.tok_out)
                  - EDGE_PROFILE.latency(st.tok_in, st.tok_out))
            dk = CLOUD_PROFILE.cost(st.tok_in, st.tok_out)
            cs.append(normalized_cost(dl, dk))
        # value proxy: û·c ≈ Δq (û approximates Δq/c)
        vals = [float(u) * c for u, c in zip(u_hat, cs)]
        r, _ = knapsack_oracle(vals, cs, self.budget)
        return {st.sid: int(r[i]) for i, st in enumerate(query.subtasks)}

    def decide(self, query, node, ctx):
        if query.qid not in self._alloc:
            self._alloc[query.qid] = self._solve(query)
        return self._alloc[query.qid].get(node.sid, 0), {}


class HybridFlowPolicy(_BasePolicy):
    """Learned utility + online dual thresholding (+ optional LinUCB).

    Fresh per query (threshold state is per-query budget tracking, as in
    App. C Eq. 27); the bandit calibrator persists across queries.
    """

    # Defaults retuned for this world model's cost scale (paper: τ0=0.2,
    # K_max=0.02, L_max=20 — same structure, different operating point).
    def __init__(self, router: Router, *, tau0: float = 0.35,
                 k_max: float = 0.04, l_max: float = 40.0,
                 calibrator: Optional[LinUCBCalibrator] = None,
                 wm: Optional[WorldModel] = None):
        self.router = router
        self.tau0 = tau0
        self.k_max = k_max
        self.l_max = l_max
        self.calibrator = calibrator
        self.wm = wm                      # feedback source for the bandit
        self._thr: Dict[str, TwoBudgetThreshold] = {}
        self._pending: Dict[Tuple[str, int], Tuple[float, List[float], float]] = {}

    def _threshold(self, qid: str) -> TwoBudgetThreshold:
        if qid not in self._thr:
            self._thr[qid] = TwoBudgetThreshold(
                tau0=self.tau0, k_max=self.k_max, l_max=self.l_max)
        return self._thr[qid]

    def _context_features(self, node, thr) -> List[float]:
        return [1.0 - thr.c_used, len(node.deps) / 4.0,
                1.0 if node.role == "GENERATE" else 0.0]

    def decide(self, query, node, ctx):
        thr = self._threshold(query.qid)
        # "real-time budget status": latency pressure is the wall-clock
        # elapsed on this query at decision time (parallel execution means
        # elapsed < Σ latencies — the scheduler provides the clock)
        if "clock" in ctx.extra:
            thr.l_used = ctx.extra["clock"]
        u_hat = self.router.predict_one(node.desc, thr.c_used)
        tau_t = thr.tau
        if self.calibrator is not None:
            s = self._context_features(node, thr)
            u_bar = self.calibrator.ucb(u_hat, s)
            self._pending[(query.qid, node.sid)] = (u_hat, s, tau_t)
        else:
            u_bar = u_hat
        ctx.tau_trace.append(tau_t)
        r = int(u_bar > tau_t)
        return r, {"u_hat": u_hat, "u_bar": u_bar, "tau": tau_t}

    def observe(self, query, node, r, result, ctx):
        thr = self._threshold(query.qid)
        thr.spend(dk=result.api_cost, dl=0.0)  # latency tracked by wall clock
        if self.calibrator is not None and r == 1 and self.wm is not None:
            key = (query.qid, node.sid)
            if key in self._pending:
                u_hat, s, tau_t = self._pending.pop(key)
                st = next((x for x in query.subtasks if x.sid == node.sid), None)
                if st is not None:
                    dq, dl, dk = self.wm.deltas(query, st)
                    from repro.core.utility import normalized_cost, utility
                    from repro.core.profiler import UTILITY_GAMMA
                    # utility-scale feedback (same scale as û; Eq. 14's
                    # λ-penalty is carried by the threshold instead — a
                    # scale-consistent variant, see DESIGN.md)
                    rew = utility(dq, normalized_cost(dl, dk)) ** UTILITY_GAMMA
                    self.calibrator.update(u_hat, s, rew)


# --------------------------------------------------------------------------
# method runners
# --------------------------------------------------------------------------

@dataclass
class MethodOutput:
    name: str
    results: List[QueryResult]

    @property
    def accuracy(self) -> float:
        return float(np.mean([r.final_correct for r in self.results]))

    @property
    def latency(self) -> float:
        return float(np.mean([r.latency for r in self.results]))

    @property
    def api_cost(self) -> float:
        return float(np.mean([r.api_cost for r in self.results]))

    @property
    def offload_rate(self) -> float:
        rates = [r.offload_rate for r in self.results if r.offload]
        return float(np.mean(rates)) if rates else 0.0


@dataclass
class Pipeline:
    """Bundles the world model, planner and executors for one deployment."""

    wm: WorldModel = field(default_factory=WorldModel)
    planner: SyntheticPlanner = field(default_factory=SyntheticPlanner)
    edge_concurrency: int = 1      # one on-device accelerator
    cloud_concurrency: int = 8     # API parallelism

    def __post_init__(self):
        self.edge = WorldModelExecutor(self.wm, cloud=False,
                                       concurrency=self.edge_concurrency)
        self.cloud = WorldModelExecutor(self.wm, cloud=True,
                                        concurrency=self.cloud_concurrency)

    # ---- plan helpers -------------------------------------------------
    def plan(self, query: Query) -> Tuple[PlanDAG, str]:
        return self.planner.plan(query)

    # ---- method drivers -------------------------------------------------
    # Direct prompting solves the whole query in one draw at elevated
    # difficulty AND must not skip a needed reasoning step (completeness
    # factor). Calibrated to Table 1 direct-prompt anchors
    # (L3B 16.9 / G4.1 51.8 on GPQA).
    DIRECT_OFFSET = 0.30
    DIRECT_COMPLETENESS = 0.80

    def direct(self, queries: Sequence[Query], model: str) -> MethodOutput:
        """Single-prompt baseline: no decomposition benefit."""
        cloud = model == "cloud"
        prof = self.wm.profile(int(cloud))
        out = []
        for q in queries:
            d_agg = float(np.clip(np.mean([s.difficulty for s in q.subtasks])
                                  + self.DIRECT_OFFSET, 0, 1))
            tok_in = sum(s.tok_in for s in q.subtasks) // 2
            tok_out = int(sum(s.tok_out for s in q.subtasks) * 0.7)
            p = prof.p_correct(d_agg) * self.DIRECT_COMPLETENESS
            u = self.wm._u(q, -1)
            res = SubtaskResult(0, int(cloud), u < p,
                                prof.latency(tok_in, tok_out),
                                prof.cost(tok_in, tok_out), tok_in, tok_out)
            dag = chain_fallback(self.planner.true_dag(q))
            out.append(QueryResult(q.qid, res.correct, res.latency,
                                   res.api_cost, {0: res}, {}, [], dag))
        return MethodOutput(f"direct-{model}", out)

    def cot(self, queries: Sequence[Query], model: str) -> MethodOutput:
        pol = StaticPolicy(int(model == "cloud"))
        res = [self._run(q, pol, chain=True) for q in queries]
        return MethodOutput(f"cot-{model}", res)

    def sot(self, queries: Sequence[Query], model: str) -> MethodOutput:
        pol = StaticPolicy(int(model == "cloud"))
        out = []
        for q in queries:
            dag, status = self.plan(q)
            out.append(run_parallel_ignore_deps(q, dag, pol, self.edge, self.cloud))
        return MethodOutput(f"sot-{model}", out)

    def pasta(self, queries: Sequence[Query], model: str,
              keep_edge_prob: float = 0.5) -> MethodOutput:
        """Partial dependency respect: each edge survives w.p. keep_edge_prob."""
        pol = StaticPolicy(int(model == "cloud"))
        out = []
        for q in queries:
            dag, status = self.plan(q)
            rng = _rng("pasta", q.qid)
            nodes = []
            for nd in dag.nodes:
                deps = tuple(d for d in nd.deps
                             if rng.random() < keep_edge_prob)
                nodes.append(replace(nd, deps=deps,
                                     requires=tuple(f"r{d}" for d in deps)))
            out.append(run_query(q, PlanDAG(tuple(nodes)), pol,
                                 self.edge, self.cloud, plan_status=status))
        return MethodOutput(f"pasta-{model}", out)

    def hybridllm(self, queries: Sequence[Query], router: Router,
                  tau: float = 0.35) -> MethodOutput:
        pol = QueryLevelPolicy(router, tau)
        res = [self._run(q, pol, chain=True) for q in queries]
        return MethodOutput("hybridllm", res)

    def dot(self, queries: Sequence[Query], router: Router,
            tau0: float = 0.5) -> MethodOutput:
        pol = FixedThresholdPolicy(router, tau0)
        res = [self._run(q, pol, chain=True) for q in queries]
        return MethodOutput("dot", res)

    def random(self, queries: Sequence[Query], p: float = 0.42,
               seed: int = 0, *, chain: bool = True) -> MethodOutput:
        """Table 3 Random arm (sequential like the paper's ablation rows)."""
        pol = RandomPolicy(p, seed)
        res = [self._run(q, pol, chain=chain) for q in queries]
        return MethodOutput("random", res)

    def fixed(self, queries: Sequence[Query], router: Router,
              tau0: float = 0.5, *, chain: bool = True) -> MethodOutput:
        """Table 3/6 fixed-threshold arm (sequential; the paper's τ0=0 row
        reproduces CoT-cloud latency, so the sweep is chain-mode)."""
        pol = FixedThresholdPolicy(router, tau0)
        res = [self._run(q, pol, chain=chain) for q in queries]
        return MethodOutput(f"fixed-{tau0}", res)

    def knapsack(self, queries: Sequence[Query], router: Router,
                 budget: float = 0.5) -> MethodOutput:
        """Beyond-paper batch-DP allocation arm (upper baseline)."""
        pol = KnapsackPolicy(router, budget)
        res = [self._run(q, pol) for q in queries]
        return MethodOutput(f"knapsack-{budget}", res)

    def hybridflow(self, queries: Sequence[Query], router: Router, *,
                   chain: bool = False, calibrate: bool = False,
                   tau0: float = 0.35, k_max: float = 0.04,
                   l_max: float = 40.0,
                   schedules: Optional[List[Schedule]] = None) -> MethodOutput:
        cal = LinUCBCalibrator(dim=3) if calibrate else None
        pol = HybridFlowPolicy(router, tau0=tau0, k_max=k_max, l_max=l_max,
                               calibrator=cal, wm=self.wm)
        res = []
        for q in queries:
            sched = Schedule() if schedules is not None else None
            res.append(self._run(q, pol, chain=chain, schedule_out=sched))
            if schedules is not None:
                schedules.append(sched)
        return MethodOutput("hybridflow-chain" if chain else "hybridflow", res)

    def _run(self, q: Query, pol: RoutingPolicy, *, chain: bool = False,
             schedule_out: Optional[Schedule] = None) -> QueryResult:
        dag, status = self.plan(q)
        return run_query(q, dag, pol, self.edge, self.cloud, chain=chain,
                         plan_status=status, schedule_out=schedule_out)
