"""Subtask embedding model.

Stand-in for qwen3-embedding-0.6b (unavailable offline): a deterministic
hashed n-gram featurizer followed by a small JAX projection encoder.
The contract matches the paper's: z_i = embedding(t_i) ∈ R^dim, consumed
by the router MLP. Swap in any real encoder via the same ``embed_texts``
signature.
"""
from __future__ import annotations

import hashlib
import math
import re
from functools import lru_cache
from typing import List, Sequence

import numpy as np

DIM = 64
_N_HASH = 4096


def _hash(tokenish: str) -> int:
    return int.from_bytes(hashlib.md5(tokenish.encode()).digest()[:4], "little")


def _tokens(text: str) -> List[str]:
    return re.findall(r"[a-zA-Z][a-zA-Z\-]+|\d+", text.lower())


@lru_cache(maxsize=1)
def _projection(dim: int = DIM, seed: int = 13) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.normal(0, 1.0 / math.sqrt(dim), size=(_N_HASH, dim)).astype(np.float32)


def featurize(text: str) -> np.ndarray:
    """Sparse hashed unigram+bigram counts -> [_N_HASH] (l2-normalized)."""
    toks = _tokens(text)
    feats = toks + [f"{a}_{b}" for a, b in zip(toks, toks[1:])]
    v = np.zeros(_N_HASH, np.float32)
    for f in feats:
        h = _hash(f)
        v[h % _N_HASH] += 1.0 if (h >> 16) % 2 else -1.0  # signed hashing
    n = np.linalg.norm(v)
    return v / n if n > 0 else v


def embed_texts(texts: Sequence[str], dim: int = DIM) -> np.ndarray:
    """[n, dim] float32 embeddings."""
    P = _projection(dim)
    out = np.stack([featurize(t) @ P for t in texts]) if texts else \
        np.zeros((0, dim), np.float32)
    # append cheap scalar stats (length features carry token-count signal)
    extra = np.array([[len(t) / 200.0, len(_tokens(t)) / 40.0] for t in texts],
                     np.float32) if texts else np.zeros((0, 2), np.float32)
    out = np.concatenate([out, extra], axis=1)
    return out.astype(np.float32)


def embedding_dim(dim: int = DIM) -> int:
    return dim + 2
