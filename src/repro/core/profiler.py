"""Offline profiling & credit assignment (paper App. C "Quality and Cost
Estimation").

Builds the router's training set from held-out queries (MMLU-Pro split +
Math500): every subtask is executed once on edge and once on cloud with
cached outputs; mixed executions are recombined by sampling routing
vectors; Δq_i is the average marginal effect of toggling subtask i
(common random numbers make the counterfactual well-defined). Targets are
u_i = clip(Δq_i / (c_i + ε), 0, 1) per Eq. 25.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core import embeddings as E
from repro.core.utility import normalized_cost, utility
from repro.core.router import RouterConfig, Router, make_features, train_router
from repro.data.tasks import Query, WorldModel, gen_benchmark, _rng


@dataclass
class ProfiledSubtask:
    qid: str
    sid: int
    desc: str
    dq: float
    dl: float
    dk: float
    c: float
    u: float


def profile_queries(queries: Sequence[Query], wm: WorldModel, *,
                    n_recombine: int = 16, seed: int = 0,
                    exact: bool = False) -> List[ProfiledSubtask]:
    """Reuse-and-recombine marginal credit assignment (App. C)."""
    out: List[ProfiledSubtask] = []
    for q in queries:
        n = q.n
        rng = _rng("profile", seed, q.qid)
        routings = [dict(zip([s.sid for s in q.subtasks],
                             rng.integers(0, 2, size=n)))
                    for _ in range(n_recombine)]
        for st in q.subtasks:
            if exact:
                dq, dl, dk = wm.deltas(q, st)
            else:
                dqs = []
                for r in routings:
                    r1 = dict(r)
                    r1[st.sid] = 1
                    r0 = dict(r)
                    r0[st.sid] = 0
                    dqs.append(float(wm.final_correct(q, r1))
                               - float(wm.final_correct(q, r0)))
                dq = float(np.mean(dqs))
                dl = wm.latency(st, 1) - wm.latency(st, 0)
                dk = wm.cost(st, 1) - wm.cost(st, 0)
            c = normalized_cost(dl, dk)
            out.append(ProfiledSubtask(q.qid, st.sid, st.desc, dq, dl, dk,
                                       c, utility(dq, c)))
    return out


UTILITY_GAMMA = 0.55  # monotone recalibration: aligns the û scale with the
#                       paper's (their profiled utilities have median ≈0.45;
#                       raw dq/(c+ε) here has median ≈0.26). Monotone, so the
#                       threshold/knapsack structure is unchanged.


def build_training_set(profiled: Sequence[ProfiledSubtask], *, seed: int = 0,
                       gamma: float = UTILITY_GAMMA
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """(features, targets) for router regression. The budget feature is
    drawn uniformly (targets are budget-independent; the threshold handles
    budget pressure at decision time)."""
    rng = np.random.default_rng(seed)
    z = E.embed_texts([p.desc for p in profiled])
    c_used = rng.uniform(0, 1, size=len(profiled)).astype(np.float32)
    x = make_features(z, c_used)
    y = np.array([p.u for p in profiled], np.float32) ** gamma
    return x, y


def train_default_router(*, n_queries: int = 400, seed: int = 0,
                         wm: WorldModel | None = None,
                         epochs: int = 150, exact: bool = True
                         ) -> Tuple[Router, Dict]:
    """End-to-end offline warm-start on the paper's profiling mix
    (MMLU-Pro held-out + Math500, 2000 queries in the paper — scaled here)."""
    wm = wm or WorldModel()
    qs = (gen_benchmark("mmlu_pro", n_queries // 2, seed=seed + 1000)
          + gen_benchmark("math500", n_queries - n_queries // 2, seed=seed))
    prof = profile_queries(qs, wm, exact=exact, seed=seed)
    x, y = build_training_set(prof, seed=seed)
    # paper trains at AdamW lr 1e-4 over 2000-query profiles; our scaled-down
    # profile needs a proportionally larger step to converge in few epochs
    cfg = RouterConfig(epochs=epochs, seed=seed, lr=5e-4)
    params, hist = train_router(cfg, x, y)
    info = {"n_samples": len(y), "final_mse": hist[-1], "history": hist,
            "target_mean": float(np.mean(y))}
    return Router(params, cfg), info
