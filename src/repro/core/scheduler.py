"""Dependency-aware subtask scheduling (paper Algorithm 1, Stage 2).

Event-driven executor over a PlanDAG: subtasks enter the ready queue the
moment their parents complete; each ready subtask is routed by a pluggable
policy and dispatched to an edge or cloud worker pool. Wall-clock latency
is the simulated makespan (edge pool has limited concurrency — the single
on-device GPU; the cloud API pool is wide), matching the paper's
concurrent edge/cloud execution. ``chain=True`` forces sequential
topological execution (HybridFlow-Chain ablation).

The event loop lives in ``FleetScheduler``, which multiplexes the ready
queues of *many* concurrent queries onto the same shared edge/cloud pools
(the fleet is the scheduling unit, not the single query): round-robin
dispatch for fairness, bounded admission, an optional *global*
TwoBudgetThreshold that forces edge execution once the fleet-wide budget
is exhausted, and optional cloud→edge spill under pool saturation.
``run_query`` is the single-query view of the same loop and reproduces
the paper's per-query Algorithm 1 exactly.

The same scheduler drives either the analytic WorldModel executor (used
for benchmark tables) or real JAX-model executors from repro.serving
(used in examples/integration tests) through the Executor protocol.

Failure semantics (``retry=RetryPolicy(...)``): an executor raising from
``run``/``submit``, or a dispatched subtask exceeding ``timeout_s``, is
retried up to ``max_retries`` times with capped exponential backoff;
a *cloud* subtask that exhausts its retries degrades to the edge
executor through the same path spill uses (its attempt counter resets —
the edge is a different resource). Only an edge-side exhaustion (or
``degrade_to_edge=False``) surfaces as an error. Timed-out attempts are
charged against the per-query and global budgets (tokens already
generated cost real money even when discarded), so the utility model
stays honest under faults. With ``retry=None`` (default) any executor
exception propagates unchanged — exactly the pre-fault-tolerance
behavior, and fault-free runs are bit-identical either way.
"""
from __future__ import annotations

import heapq
import itertools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.core.dag import PlanDAG, Node, topological_order
from repro.core.dual import TwoBudgetThreshold
from repro.data.tasks import Query, Subtask, WorldModel


class Executor(Protocol):
    """One side of the edge/cloud pair."""

    concurrency: int

    def run(self, query: Query, node: Node, dep_results: Dict[int, "SubtaskResult"]
            ) -> "SubtaskResult": ...


class RoutingPolicy(Protocol):
    def decide(self, query: Query, node: Node, ctx: "SchedulerContext"
               ) -> Tuple[int, Dict]: ...

    def observe(self, query: Query, node: Node, r: int,
                result: "SubtaskResult", ctx: "SchedulerContext") -> None: ...


@dataclass
class SubtaskResult:
    sid: int
    routed_cloud: int
    correct: bool
    latency: float
    api_cost: float
    tok_in: int
    tok_out: int
    answer: str = ""
    retries: int = 0           # failed attempts absorbed before this result
    degraded: bool = False     # cloud subtask that fell back to the edge


@dataclass(frozen=True)
class RetryPolicy:
    """Scheduler-side recovery knobs (see module docstring for the
    contract). ``backoff(n)`` is the delay before attempt ``n``'s
    re-dispatch: ``min(cap, base * 2**(n-1))``."""

    max_retries: int = 2
    backoff_base: float = 0.05
    backoff_cap: float = 2.0
    timeout_s: Optional[float] = None   # per-attempt deadline; None = off
    degrade_to_edge: bool = True

    def backoff(self, attempt: int) -> float:
        if attempt <= 0:
            return 0.0
        return min(self.backoff_cap, self.backoff_base * 2.0 ** (attempt - 1))


@dataclass(eq=False)   # identity semantics: one pending dispatch attempt
class _Dispatch:
    """A routed subtask waiting for (or holding) an executor slot, with
    its retry lineage. Mutated in place across attempts so the recovery
    path (retry → degrade) carries state without re-routing."""

    r: int
    node: Node
    attempt: int = 0           # failures on the CURRENT side (resets on
    #                            degrade: the edge is a fresh resource)
    retries: int = 0           # total failed attempts, both sides
    degraded: bool = False
    not_before: float = 0.0    # backoff gate (fleet-clock seconds)
    hint: Optional[object] = None  # KV prefix hint (shared-context token
    #                            ids); computed once at first dispatch and
    #                            carried — because the dispatch is mutated
    #                            in place — across retry, cloud→edge
    #                            spill, and degradation re-dispatch, so a
    #                            re-routed subtask still lands where its
    #                            query's context is hot


@dataclass
class SchedulerContext:
    """Mutable per-query state visible to the routing policy."""

    k_used: float = 0.0
    l_used: float = 0.0
    position: int = 0          # how many subtasks routed so far
    tau_trace: List[float] = field(default_factory=list)
    extra: Dict = field(default_factory=dict)


@dataclass
class QueryResult:
    qid: str
    final_correct: bool
    latency: float             # makespan (s), admission -> final subtask
    api_cost: float
    results: Dict[int, SubtaskResult]
    offload: Dict[int, int]
    tau_trace: List[float]
    dag: PlanDAG
    plan_status: str = "valid"
    # open-loop (timed-admission) metrics; all zero for closed-loop runs
    # where every query arrives at t=0 and admission is immediate
    arrival: float = 0.0       # fleet-clock arrival time
    queue_wait: float = 0.0    # arrival -> admission
    ttft: float = 0.0          # arrival -> first completed subtask

    @property
    def offload_rate(self) -> float:
        if not self.offload:
            return 0.0
        return float(np.mean(list(self.offload.values())))

    @property
    def n_retries(self) -> int:
        return sum(r.retries for r in self.results.values())

    @property
    def n_degraded(self) -> int:
        return sum(1 for r in self.results.values() if r.degraded)


class WorldModelExecutor:
    """Analytic executor backed by the seeded world model."""

    # executing without a needed input (dependency dropped or ignored by
    # SoT/PASTA-style schedulers) costs this factor per missing input —
    # milder than a *wrong* input (parent_penalty), matching the paper's
    # Table 1 pattern where SoT degrades CoT only moderately
    MISSING_DEP_PENALTY = 0.72

    def __init__(self, wm: WorldModel, cloud: bool, concurrency: int):
        self.wm = wm
        self.cloud = cloud
        self.concurrency = concurrency

    def run(self, query: Query, node: Node,
            dep_results: Dict[int, SubtaskResult]) -> SubtaskResult:
        st = _subtask_of(query, node)
        prof = self.wm.profile(int(self.cloud))
        p = prof.p_correct(st.difficulty)
        # penalties follow the query's GROUND-TRUTH information needs: a
        # planner/scheduler that drops an edge doesn't remove the need
        true_deps = st.deps
        n_bad = sum(1 for d in true_deps
                    if d in dep_results and not dep_results[d].correct)
        n_missing = sum(1 for d in true_deps if d not in dep_results)
        p *= self.wm.parent_penalty ** n_bad
        p *= self.MISSING_DEP_PENALTY ** n_missing
        u = self.wm._u(query, st.sid)
        # payload includes dependency answers (App. D.1): tok_in grows
        tok_in = st.tok_in + sum(dep_results[d].tok_out // 4
                                 for d in node.deps if d in dep_results)
        lat = prof.latency(tok_in, st.tok_out)
        cost = prof.cost(tok_in, st.tok_out)
        return SubtaskResult(st.sid, int(self.cloud), bool(u < p), lat, cost,
                             tok_in, st.tok_out,
                             answer=f"[{prof.name}] answer r{st.sid}")


def _saturated(ex: Executor) -> bool:
    """Whether an executor's real backing capacity is exhausted. Engine-
    backed executors expose ``saturated()`` (live KV-slot occupancy across
    every pool replica); analytic executors don't, and for them hitting
    the busy-count cap IS saturation."""
    sat = getattr(ex, "saturated", None)
    return True if sat is None else bool(sat())


def _subtask_of(query: Query, node: Node) -> Subtask:
    for st in query.subtasks:
        if st.sid == node.sid:
            return st
    # repaired/fallback plans may have synthesized filler nodes: derive one
    return Subtask(node.sid, node.desc, node.role, node.deps,
                   difficulty=0.5, tok_in=80, tok_out=120)


@dataclass
class Schedule:
    """Full event log of one query's execution (for Fig. 3 / traces)."""

    events: List[Tuple[float, float, int, int]] = field(default_factory=list)
    # (start, end, sid, routed_cloud)


@dataclass(eq=False)   # identity semantics: states are loop bookkeeping
class _QueryState:
    """Per-query bookkeeping inside the fleet event loop."""

    query: Query
    dag: PlanDAG
    policy: RoutingPolicy
    plan_status: str
    schedule_out: Optional[Schedule]
    order: List[int]
    ctx: SchedulerContext = field(default_factory=SchedulerContext)
    results: Dict[int, SubtaskResult] = field(default_factory=dict)
    offload: Dict[int, int] = field(default_factory=dict)
    indeg: Dict[int, int] = field(default_factory=dict)
    children: Dict[int, List[int]] = field(default_factory=dict)
    ready: List[Node] = field(default_factory=list)
    waiting: List["_Dispatch"] = field(default_factory=list)
    n_done: int = 0
    done_sids: set = field(default_factory=set)
    admitted: bool = False
    admit_clock: float = 0.0
    arrival: float = 0.0                # earliest admission time (open loop)
    first_done: Optional[float] = None  # fleet clock of first completion
    result: Optional[QueryResult] = None
    index: int = -1


class _LoopState:
    """Mutable shared state of one event-loop run (either driver): the
    fleet clock, per-pool busy counts, the admission backlog and the
    admitted-unfinished set."""

    __slots__ = ("clock", "busy", "backlog", "active")

    def __init__(self, fleet: "FleetScheduler"):
        self.clock = 0.0
        self.busy = {id(fleet.edge): 0, id(fleet.cloud): 0}
        # arrival order, submit order within a tie — identical to plain
        # submit order when every arrival is 0 (the closed-loop case)
        self.backlog = sorted(
            (qs for qs in fleet._states if qs.result is None),
            key=lambda qs: (qs.arrival, qs.index))
        self.active: List[_QueryState] = []    # admitted, unfinished


class FleetScheduler:
    """Shared event loop serving N queries over one edge/cloud pool pair.

    The paper's Algorithm 1 schedules a single query's DAG; the fleet
    scheduler is its multi-tenant generalization — every admitted query
    keeps its own ready queue, routing context and (policy-held) budget
    duals, while executor slots, the simulated clock and the optional
    *global* budget are shared across the fleet:

      * subtasks are routed the moment their parents complete (Algorithm 1
        pops immediately), then wait for a free slot in their target pool;
      * slot dispatch is round-robin over queries (fair: no query can
        starve another by flooding one pool), FIFO within a query;
      * ``max_inflight`` bounds concurrently-admitted queries; the rest
        queue in submit order and are admitted as earlier queries finish;
      * a global ``TwoBudgetThreshold`` (fleet-wide $ + wall-clock
        latency budget — dl is charged as the fleet clock advances, the
        same convention the per-query duals use) forces edge execution
        once exhausted (``tau >= 1``) so cloud spend is capped without
        deadlocking in-flight queries;
      * ``spill_to_edge`` re-routes a cloud-bound subtask onto an idle
        edge slot when the cloud pool is saturated (work conservation);
      * ``pump`` selects the event-loop driver: analytic executors run
        the *simulated* clock (``ex.run`` returns a latency, the heap
        advances time); async executors (``submit``/``poll``/``pump``,
        e.g. ``JAXExecutor``) run the *real-time pump loop* — every
        dispatch enqueues into its engine, the loop keeps stepping all
        engines while routing continues, and co-scheduled subtasks from
        different queries decode in the same micro-batches. ``pump=None``
        (default) auto-detects from the executor pair.

    With one submitted query, no global budget and no spill, the loop is
    step-for-step identical to the paper's per-query scheduler (the
    ``run_query`` fast path delegates here).
    """

    def __init__(self, edge: Executor, cloud: Executor, *,
                 max_inflight: Optional[int] = None,
                 global_budget: Optional[TwoBudgetThreshold] = None,
                 spill_to_edge: bool = False,
                 pump: Optional[bool] = None,
                 retry: Optional[RetryPolicy] = None,
                 stall_grace: float = 5.0):
        if getattr(edge, "concurrency", 1) < 1 or \
                getattr(cloud, "concurrency", 1) < 1:
            raise ValueError("executor pools need concurrency >= 1")
        if max_inflight is not None and max_inflight < 1:
            raise ValueError("max_inflight must be >= 1 (or None)")
        self.edge = edge
        self.cloud = cloud
        self.max_inflight = max_inflight
        self.global_budget = global_budget
        self.spill_to_edge = spill_to_edge
        self.pump = pump
        self.retry = retry
        # with retry enabled the pumped driver tolerates idle passes (back-
        # off gates + injected stalls park work with nothing to step) up to
        # this many seconds before declaring the fleet stalled
        self.stall_grace = stall_grace
        self.makespan = 0.0
        self.stats = {"forced_edge": 0, "spills": 0, "peak_inflight": 0,
                      "dispatched": 0, "retries": 0, "timeouts": 0,
                      "degraded": 0, "exec_faults": 0, "fault_cost": 0.0}
        self._states: List[_QueryState] = []

    def _async_capable(self) -> bool:
        return all(hasattr(ex, "submit") and hasattr(ex, "poll")
                   and hasattr(ex, "pump")
                   for ex in (self.edge, self.cloud))

    # ---- admission ----------------------------------------------------
    def submit(self, query: Query, dag: PlanDAG, policy: RoutingPolicy, *,
               plan_status: str = "valid",
               schedule_out: Optional[Schedule] = None,
               arrival: float = 0.0) -> int:
        """Enqueue one planned query; returns its fleet index.

        ``arrival`` (fleet-clock seconds, default 0) is the earliest time
        the query may be admitted — open-loop traces submit every query
        up front with its arrival time and the loop admits each one when
        the clock reaches it.  ``arrival=0`` for every query is the
        closed-loop case and leaves both drivers' behavior untouched.
        """
        if dag.n == 0:
            raise ValueError("scheduler requires a non-empty DAG")
        if arrival < 0:
            raise ValueError("arrival must be >= 0")
        order = topological_order(dag)
        if order is None:
            raise ValueError("scheduler requires a DAG (run repair first)")
        qs = _QueryState(query, dag, policy, plan_status, schedule_out, order)
        qs.arrival = float(arrival)
        # dangling deps (sid not in the DAG) are ignored, matching
        # topological_order/children — otherwise the node never becomes
        # ready and the query stalls holding an admission slot forever
        sids = set(dag.sids)
        qs.indeg = {nd.sid: sum(1 for d in nd.deps if d in sids)
                    for nd in dag.nodes}
        qs.children = {nd.sid: dag.children(nd.sid) for nd in dag.nodes}
        qs.index = len(self._states)
        self._states.append(qs)
        return qs.index

    # ---- event loop ---------------------------------------------------
    def run(self) -> List[QueryResult]:
        """Drain all submitted queries; results come back in submit order."""
        use_pump = self.pump if self.pump is not None else \
            self._async_capable()
        if use_pump:
            if not self._async_capable():
                raise ValueError("pump=True needs executors with "
                                 "submit/poll/pump (e.g. JAXExecutor)")
            return self._run_pumped()
        return self._run_sim()

    def _observe_completion(self, qs: _QueryState, node: Node, r: int,
                            res: SubtaskResult, start: float, end: float,
                            prev_clock: float,
                            disp: Optional[_Dispatch] = None) -> None:
        """Shared completion bookkeeping for both event-loop drivers:
        charge per-query and fleet budgets (dl is the fleet clock advance,
        NOT the per-subtask latency sum, which would scale with
        concurrency), notify the policy, log the schedule event and
        unlock children into the ready queue."""
        if disp is not None:
            res.retries = disp.retries
            res.degraded = disp.degraded
        if qs.first_done is None:
            qs.first_done = end    # TTFT anchor: first visible output
        qs.done_sids.add(node.sid)
        qs.ctx.k_used += res.api_cost
        qs.ctx.l_used += res.latency
        if self.global_budget is not None:
            self.global_budget.spend(dk=res.api_cost, dl=end - prev_clock)
        qs.policy.observe(qs.query, node, r, res, qs.ctx)
        if qs.schedule_out is not None:
            qs.schedule_out.events.append(
                (start - qs.admit_clock, end - qs.admit_clock, node.sid, r))
        for c in qs.children[node.sid]:
            qs.indeg[c] -= 1
            if qs.indeg[c] == 0:
                qs.ready.append(qs.dag.node(c))
        qs.n_done += 1

    # ---- fault recovery ------------------------------------------------
    def _charge_fault(self, qs: _QueryState, cost: float, elapsed: float,
                      dl: float = 0.0) -> None:
        """A failed/timed-out attempt still spent real resources: charge
        the per-query duals (cost + wasted wall-clock) and the global $
        budget. ``dl`` is the global-clock advance not yet charged by a
        completion (the drivers keep the dl chain gap-free)."""
        qs.ctx.k_used += cost
        qs.ctx.l_used += elapsed
        self.stats["fault_cost"] += cost
        if self.global_budget is not None:
            self.global_budget.spend(dk=cost, dl=dl)

    def _handle_fault(self, qs: _QueryState, disp: _Dispatch,
                      err: BaseException, requeue) -> None:
        """Recovery decision for one failed attempt (executor raise or
        deadline timeout): retry with backoff while attempts remain, then
        degrade cloud→edge, then surface. ``requeue(qs, disp, delay)`` is
        driver-specific (sim: heap event; pump: not_before gate)."""
        if self.retry is None:
            raise err
        disp.attempt += 1
        disp.retries += 1
        if disp.attempt <= self.retry.max_retries:
            self.stats["retries"] += 1
            requeue(qs, disp, self.retry.backoff(disp.attempt))
        elif disp.r == 1 and self.retry.degrade_to_edge:
            # cloud exhausted: re-route to the edge through the offload
            # map (same bookkeeping the spill path uses); the edge is a
            # fresh resource, so its attempt counter starts over
            disp.r = 0
            disp.attempt = 0
            disp.degraded = True
            qs.offload[disp.node.sid] = 0
            self.stats["degraded"] += 1
            requeue(qs, disp, 0.0)
        else:
            raise RuntimeError(
                f"subtask (qid={qs.query.qid}, sid={disp.node.sid}) failed "
                f"after {disp.retries} retries on "
                f"{'cloud' if disp.r else 'edge'}"
                + (" (degraded)" if disp.degraded else "")) from err

    def _make_loop(self, st: "_LoopState", dispatch_action, fail_action,
                   live_saturation: bool = False):
        """Build the admission/routing/dispatch closures shared by both
        event-loop drivers; only the dispatch *action* differs (sim:
        ``ex.run`` + heap push; pump: ``ex.submit`` into the engine).
        Keeping these in one place is what preserves the forced-edge
        budget rule, spill policy and round-robin fairness as a single
        behavior across drivers. ``live_saturation`` (pump driver only)
        additionally gates spill on the executor's real slot occupancy —
        meaningless under the sim driver, whose requests never stay
        resident in an engine between dispatches."""

        def admit_next():
            while st.backlog and (self.max_inflight is None
                                  or len(st.active) < self.max_inflight):
                if st.backlog[0].arrival > st.clock:
                    break          # open loop: next query hasn't arrived yet
                qs = st.backlog.pop(0)
                qs.admitted = True
                qs.admit_clock = st.clock
                qs.ready = [qs.dag.node(s) for s in qs.order
                            if qs.indeg[s] == 0]
                st.active.append(qs)
                self.stats["peak_inflight"] = max(
                    self.stats["peak_inflight"], len(st.active))
                route_ready(qs)

        def route_ready(qs: _QueryState):
            # route every ready subtask immediately (Algorithm 1 pops as
            # soon as dependencies resolve); the policy sees the query's
            # own elapsed clock, not the fleet clock
            for node in list(qs.ready):
                qs.ready.remove(node)
                qs.ctx.extra["clock"] = st.clock - qs.admit_clock
                r, _info = qs.policy.decide(qs.query, node, qs.ctx)
                if (r and self.global_budget is not None
                        and self.global_budget.tau >= 1.0):
                    r = 0
                    self.stats["forced_edge"] += 1
                qs.offload[node.sid] = r
                qs.ctx.position += 1
                qs.waiting.append(_Dispatch(r, node))

        def dispatch_one(qs: _QueryState) -> bool:
            for j, disp in enumerate(qs.waiting):
                if disp.not_before > st.clock:
                    continue           # still backing off after a failure
                ex = self.cloud if disp.r else self.edge
                if st.busy[id(ex)] >= ex.concurrency:
                    # pumped driver: spill-to-edge fires only when the
                    # cloud is REALLY out of capacity — engine-backed
                    # executors report live slot occupancy via
                    # saturated() (a replica pool is saturated only
                    # when EVERY replica is full). Sim driver and
                    # executors without the hook: hitting the busy-count
                    # cap (the check that just failed above) IS
                    # saturation
                    if not (self.spill_to_edge and disp.r == 1
                            and st.busy[id(self.edge)]
                            < self.edge.concurrency
                            and (not live_saturation or _saturated(ex))):
                        continue
                    ex, disp.r = self.edge, 0
                    qs.offload[disp.node.sid] = 0
                    self.stats["spills"] += 1
                qs.waiting.pop(j)
                st.busy[id(ex)] += 1
                self.stats["dispatched"] += 1
                try:
                    dispatch_action(qs, disp, ex)
                except Exception as exc:
                    # executor refused the attempt (injected fault or real
                    # submit error): the slot was never really taken
                    st.busy[id(ex)] -= 1
                    self.stats["exec_faults"] += 1
                    fail_action(qs, disp, exc)
                return True
            return False

        def dispatch_all() -> bool:
            # round-robin over admitted-unfinished queries: one dispatch
            # per query per pass until no pool slot can take any waiting
            # subtask
            any_progress = False
            progressed = True
            while progressed:
                progressed = False
                for qs in st.active:
                    if qs.waiting:
                        progressed |= dispatch_one(qs)
                any_progress |= progressed
            return any_progress

        return admit_next, route_ready, dispatch_all

    def _stuck_dump(self, qs: _QueryState) -> str:
        """One diagnostic line for a query wedged in the loop: where every
        node sits (done / in flight / waiting+backoff / ready / blocked)
        and the budget state — enough to debug a deadlock under faults
        without re-running with a debugger attached."""
        waiting = [(d.node.sid, "cloud" if d.r else "edge", d.attempt,
                    round(d.not_before, 3)) for d in qs.waiting]
        pend = {d.node.sid for d in qs.waiting} | {n.sid for n in qs.ready}
        inflight = sorted(set(qs.offload) - qs.done_sids - pend)
        blocked = sorted(s for s, d in qs.indeg.items()
                         if d > 0 and s not in qs.done_sids)
        return (f"  qid={qs.query.qid}: admitted={qs.admitted} "
                f"done={qs.n_done}/{qs.dag.n} "
                f"ready={sorted(n.sid for n in qs.ready)} "
                f"waiting(sid,side,attempt,not_before)={waiting} "
                f"inflight={inflight} blocked(indeg>0)={blocked} "
                f"k_used={qs.ctx.k_used:.4f} l_used={qs.ctx.l_used:.3f}")

    def _collect_results(self) -> List[QueryResult]:
        stuck = [qs for qs in self._states if qs.result is None]
        if stuck:
            dump = "\n".join(self._stuck_dump(qs) for qs in stuck)
            raise RuntimeError(
                f"fleet drained with unfinished queries (scheduler bug or "
                f"malformed DAG): {[qs.query.qid for qs in stuck]}\n{dump}")
        return [qs.result for qs in self._states]

    def _run_sim(self) -> List[QueryResult]:
        """Simulated-clock driver (analytic executors). Faults become heap
        events like completions: an attempt whose analytic latency exceeds
        ``timeout_s`` schedules a "timeout" event instead of a "done" (the
        slot is held until the deadline fires, as it would be live), and a
        retry waits out its backoff as a "retry" event so the clock keeps
        advancing and the loop can never spin on a backoff gate."""
        st = _LoopState(self)
        counter = itertools.count()
        timeout_s = self.retry.timeout_s if self.retry is not None else None
        # heap rows: (time, tick, kind, qi, dispatch, start, result) with
        # kind in {"done", "timeout", "retry"}; tick breaks all ties so
        # ordering never compares beyond (time, tick)
        running: List[Tuple] = []
        # fleet clock already charged to the global dl budget: "done" pops
        # charge the full advance since the last charge, so interleaved
        # fault events leave no gaps and fault-free runs charge exactly
        # the original prev_clock chain
        dl_mark = 0.0

        def dispatch_action(qs, disp, ex):
            res = ex.run(qs.query, disp.node, qs.results)
            if timeout_s is not None and res.latency > timeout_s:
                heapq.heappush(running, (st.clock + timeout_s,
                                         next(counter), "timeout", qs.index,
                                         disp, st.clock, res))
                return
            heapq.heappush(running, (st.clock + res.latency, next(counter),
                                     "done", qs.index, disp, st.clock, res))
            qs.results[disp.node.sid] = res  # provisional (fields final)

        def requeue(qs, disp, delay):
            heapq.heappush(running, (st.clock + delay, next(counter),
                                     "retry", qs.index, disp, st.clock,
                                     None))

        def fail_action(qs, disp, exc):
            self._handle_fault(qs, disp, exc, requeue)

        admit_next, route_ready, dispatch_all = self._make_loop(
            st, dispatch_action, fail_action)
        # open loop: each future arrival is a heap event; clock 0 arrivals
        # go through the legacy immediate admission below, so closed-loop
        # runs see an identical event sequence
        for qs_ in st.backlog:
            if qs_.arrival > 0.0:
                heapq.heappush(running, (qs_.arrival, next(counter),
                                         "arrive", qs_.index, None,
                                         qs_.arrival, None))
        admit_next()
        dispatch_all()
        while running:
            t, _, kind, qi, disp, start, res = heapq.heappop(running)
            qs = self._states[qi]
            if kind == "arrive":
                st.clock = max(st.clock, t)
                admit_next()
                dispatch_all()
                continue
            if kind == "retry":
                st.clock = t
                disp.not_before = 0.0
                qs.waiting.append(disp)
                dispatch_all()
                continue
            if kind == "timeout":
                st.clock = t
                st.busy[id(self.cloud if disp.r else self.edge)] -= 1
                self.stats["timeouts"] += 1
                self._charge_fault(qs, res.api_cost, timeout_s,
                                   dl=t - dl_mark)
                dl_mark = t
                self._handle_fault(
                    qs, disp, RuntimeError(
                        f"subtask (qid={qs.query.qid}, "
                        f"sid={disp.node.sid}) exceeded deadline "
                        f"{timeout_s}s (analytic latency "
                        f"{res.latency:.3f}s)"), requeue)
                dispatch_all()
                continue
            prev_clock, st.clock = dl_mark, t
            dl_mark = t
            ex = self.cloud if disp.r else self.edge
            st.busy[id(ex)] -= 1
            self._observe_completion(qs, disp.node, disp.r, res, start,
                                     st.clock, prev_clock, disp=disp)
            route_ready(qs)
            if qs.n_done == qs.dag.n:
                self._finalize(qs, st.clock)
                st.active.remove(qs)
                admit_next()
            dispatch_all()

        self.makespan = st.clock
        return self._collect_results()

    # reprolint: hot
    def _run_pumped(self) -> List[QueryResult]:
        """Real-time driver for async executors: dispatch = ``submit`` into
        the executor's engine; a pump loop then steps every engine while
        completions are polled, so subtasks co-scheduled on one engine
        decode in the same micro-batches and the fleet clock is genuine
        wall-clock (``makespan`` == elapsed seconds)."""
        t0 = time.perf_counter()
        st = _LoopState(self)
        prev_clock = 0.0
        timeout_s = self.retry.timeout_s if self.retry is not None else None
        idle_since = 0.0
        pools = list({id(ex): ex for ex in (self.edge, self.cloud)}.values())
        # in-flight rows: [future, qs, dispatch, executor, start_clock]
        inflight: List[List] = []

        def dispatch_action(qs, disp, ex):
            kw = {}
            if getattr(ex, "accepts_prefix_hint", False):
                if disp.hint is None:
                    # computed once per dispatch; the in-place-mutated
                    # _Dispatch carries it across retry / spill / degrade
                    disp.hint = ex.shared_context(qs.query)
                kw["prefix_hint"] = disp.hint
            fut = ex.submit(qs.query, disp.node, qs.results, **kw)
            inflight.append([fut, qs, disp, ex, st.clock])

        def requeue(qs, disp, delay):
            # re-dispatch happens from the normal waiting queue once the
            # fleet clock passes the backoff gate
            disp.not_before = st.clock + delay
            qs.waiting.append(disp)

        def fail_action(qs, disp, exc):
            self._handle_fault(qs, disp, exc, requeue)

        admit_next, route_ready, dispatch_all = self._make_loop(
            st, dispatch_action, fail_action, live_saturation=True)
        # timed admission is open-loop only; with every arrival at 0 the
        # loop below takes the exact legacy control flow (no admission
        # checks or gap naps on the hot path)
        timed = any(qs.arrival > 0.0 for qs in st.backlog)
        admit_next()
        dispatch_all()
        while inflight or any(qs.waiting for qs in st.active) \
                or (timed and st.backlog):
            stepped = False
            for ex in pools:
                stepped |= bool(ex.pump())
            st.clock = time.perf_counter() - t0
            if timed and st.backlog:
                admit_next()
                if dispatch_all():
                    # freshly arrived work was placed; poll it next pass
                    idle_since = st.clock
                    continue
                if not inflight and not any(qs.waiting
                                            for qs in st.active):
                    # traffic gap: everything admitted has drained and the
                    # next arrival is in the future — keep pumping pools
                    # (autoscalers tick on wall-clock) and nap briefly
                    time.sleep(min(max(st.backlog[0].arrival - st.clock,
                                       0.0), 0.002))
                    idle_since = st.clock
                    continue
            fault_fired = False
            if timeout_s is not None:
                for row in [r_ for r_ in inflight
                            if st.clock - r_[4] > timeout_s]:
                    fut, qs, disp, ex, start = row
                    inflight.remove(row)
                    st.busy[id(ex)] -= 1
                    cancel = getattr(ex, "cancel", None)
                    if cancel is not None:
                        cancel(fut)
                    # tokens the engine already decoded for the abandoned
                    # attempt were paid for — charge them
                    cost_fn = getattr(ex, "attempt_cost", None)
                    cost = float(cost_fn(fut)) if cost_fn is not None \
                        else 0.0
                    self.stats["timeouts"] += 1
                    self._charge_fault(qs, cost, st.clock - start,
                                       dl=st.clock - prev_clock)
                    prev_clock = st.clock
                    self._handle_fault(
                        qs, disp, RuntimeError(
                            f"subtask (qid={qs.query.qid}, "
                            f"sid={disp.node.sid}) exceeded deadline "
                            f"{timeout_s}s in flight"), requeue)
                    fault_fired = True
            done_rows = []
            for row in inflight:
                res = row[3].poll(row[0])
                if res is not None:
                    done_rows.append((row, res))
            # same-tick completions are observed in (qid, sid) order, not
            # engine-poll order: policies shared across the fleet (e.g. a
            # HybridFlowPolicy LinUCB calibrator) then see an update
            # sequence that is stable across runs/replica counts even
            # when co-batched subtasks finish on the same pump pass
            done_rows.sort(key=lambda dr: (dr[0][1].query.qid,
                                           dr[0][2].node.sid))
            if not done_rows and not fault_fired:
                if self.retry is None:
                    # pre-recovery contract, preserved exactly: an idle
                    # pass with work in flight is a wiring bug
                    if not stepped:
                        raise RuntimeError(
                            "fleet pump stalled: subtasks in flight but "
                            "every engine is idle (executor/engine "
                            "mismatch?)")
                    continue
                # recovery enabled: idle passes are expected (backoff
                # gates, injected stalls) — give backoff-expired work a
                # dispatch chance, and only past the grace window does an
                # idle fleet become a hard stall
                if bool(dispatch_all()) or stepped:
                    idle_since = st.clock
                elif st.clock - idle_since > self.stall_grace:
                    raise RuntimeError(
                        f"fleet pump stalled for "
                        f"{st.clock - idle_since:.1f}s (grace "
                        f"{self.stall_grace:.1f}s) with {len(inflight)} "
                        f"subtasks in flight:\n"
                        + "\n".join(self._stuck_dump(qs)
                                    for qs in st.active))
                else:
                    time.sleep(0.001)
                continue
            for row, res in done_rows:
                _fut, qs, disp, ex, start = row
                inflight.remove(row)
                st.busy[id(ex)] -= 1
                qs.results[disp.node.sid] = res
                self._observe_completion(qs, disp.node, disp.r, res, start,
                                         st.clock, prev_clock, disp=disp)
                prev_clock = st.clock
                route_ready(qs)
                if qs.n_done == qs.dag.n:
                    self._finalize(qs, st.clock)
                    st.active.remove(qs)
                    admit_next()
            dispatch_all()
            idle_since = st.clock

        self.makespan = st.clock
        return self._collect_results()

    def _finalize(self, qs: _QueryState, clock: float) -> None:
        gen = _generate_sid(qs.dag, qs.order)
        first = qs.first_done if qs.first_done is not None else clock
        qs.result = QueryResult(
            qs.query.qid, qs.results[gen].correct, clock - qs.admit_clock,
            sum(x.api_cost for x in qs.results.values()),
            qs.results, qs.offload, list(qs.ctx.tau_trace), qs.dag,
            qs.plan_status,
            arrival=qs.arrival,
            queue_wait=max(qs.admit_clock - qs.arrival, 0.0),
            ttft=max(first - qs.arrival, 0.0))


def run_query(query: Query, dag: PlanDAG, policy: RoutingPolicy,
              edge: Executor, cloud: Executor, *, chain: bool = False,
              plan_status: str = "valid",
              schedule_out: Optional[Schedule] = None) -> QueryResult:
    """Execute one query's DAG. Returns QueryResult with simulated makespan."""
    if dag.n == 0:
        raise ValueError("scheduler requires a non-empty DAG")
    order = topological_order(dag)
    if order is None:
        raise ValueError("scheduler requires a DAG (run repair first)")

    ctx = SchedulerContext()
    results: Dict[int, SubtaskResult] = {}
    offload: Dict[int, int] = {}

    if chain:
        # sequential topological execution (HybridFlow-Chain): still routed,
        # but no concurrency — makespan is the plain sum
        t = 0.0
        for sid in order:
            node = dag.node(sid)
            ctx.extra["clock"] = t
            r, info = policy.decide(query, node, ctx)
            ex = cloud if r else edge
            res = ex.run(query, node, results)
            results[sid] = res
            offload[sid] = r
            ctx.k_used += res.api_cost
            ctx.l_used += res.latency
            ctx.position += 1
            policy.observe(query, node, r, res, ctx)
            if schedule_out is not None:
                schedule_out.events.append((t, t + res.latency, sid, r))
            t += res.latency
        gen = _generate_sid(dag, order)
        return QueryResult(query.qid, results[gen].correct, t,
                           sum(x.api_cost for x in results.values()),
                           results, offload, list(ctx.tau_trace), dag,
                           plan_status)

    # ---- event-driven concurrent execution: single-tenant fleet ------
    fleet = FleetScheduler(edge, cloud)
    fleet.submit(query, dag, policy, plan_status=plan_status,
                 schedule_out=schedule_out)
    return fleet.run()[0]


def _generate_sid(dag: PlanDAG, order: List[int]) -> int:
    for nd in dag.nodes:
        if nd.role == "GENERATE":
            return nd.sid
    return order[-1]


def run_parallel_ignore_deps(query: Query, dag: PlanDAG, policy: RoutingPolicy,
                             edge: Executor, cloud: Executor) -> QueryResult:
    """SoT-style execution: every subtask launches at t=0 with no dependency
    context (missing-parent penalty applies). Used by baselines only."""
    ctx = SchedulerContext()
    results: Dict[int, SubtaskResult] = {}
    offload: Dict[int, int] = {}
    lat_pool: Dict[int, List[float]] = {}
    for nd in dag.nodes:
        r, _ = policy.decide(query, nd, ctx)
        ex = cloud if r else edge
        res = ex.run(query, nd, {})   # no dep results available
        results[nd.sid] = res
        offload[nd.sid] = r
        ctx.k_used += res.api_cost
        ctx.l_used += res.latency
        ctx.position += 1
        policy.observe(query, nd, r, res, ctx)
        lat_pool.setdefault(id(ex), []).append(res.latency)
    # makespan: per-pool serialization by concurrency
    makespan = 0.0
    pools = {id(edge): edge, id(cloud): cloud}
    for pid, lats in lat_pool.items():
        conc = max(pools[pid].concurrency, 1)
        # greedy LPT bound: sum/conc rounded with max item
        makespan = max(makespan, max(lats), sum(lats) / conc)
    gen = _generate_sid(dag, topological_order(dag) or [dag.nodes[-1].sid])
    return QueryResult(query.qid, results[gen].correct, makespan,
                       sum(x.api_cost for x in results.values()),
                       results, offload, list(ctx.tau_trace), dag)
