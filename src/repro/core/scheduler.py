"""Dependency-aware subtask scheduling (paper Algorithm 1, Stage 2).

Event-driven executor over a PlanDAG: subtasks enter the ready queue the
moment their parents complete; each ready subtask is routed by a pluggable
policy and dispatched to an edge or cloud worker pool. Wall-clock latency
is the simulated makespan (edge pool has limited concurrency — the single
on-device GPU; the cloud API pool is wide), matching the paper's
concurrent edge/cloud execution. ``chain=True`` forces sequential
topological execution (HybridFlow-Chain ablation).

The same scheduler drives either the analytic WorldModel executor (used
for benchmark tables) or real JAX-model executors from repro.serving
(used in examples/integration tests) through the Executor protocol.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Protocol, Tuple

import numpy as np

from repro.core.dag import PlanDAG, Node, topological_order
from repro.data.tasks import Query, Subtask, WorldModel


class Executor(Protocol):
    """One side of the edge/cloud pair."""

    concurrency: int

    def run(self, query: Query, node: Node, dep_results: Dict[int, "SubtaskResult"]
            ) -> "SubtaskResult": ...


class RoutingPolicy(Protocol):
    def decide(self, query: Query, node: Node, ctx: "SchedulerContext"
               ) -> Tuple[int, Dict]: ...

    def observe(self, query: Query, node: Node, r: int,
                result: "SubtaskResult", ctx: "SchedulerContext") -> None: ...


@dataclass
class SubtaskResult:
    sid: int
    routed_cloud: int
    correct: bool
    latency: float
    api_cost: float
    tok_in: int
    tok_out: int
    answer: str = ""


@dataclass
class SchedulerContext:
    """Mutable per-query state visible to the routing policy."""

    k_used: float = 0.0
    l_used: float = 0.0
    position: int = 0          # how many subtasks routed so far
    tau_trace: List[float] = field(default_factory=list)
    extra: Dict = field(default_factory=dict)


@dataclass
class QueryResult:
    qid: str
    final_correct: bool
    latency: float             # makespan (s)
    api_cost: float
    results: Dict[int, SubtaskResult]
    offload: Dict[int, int]
    tau_trace: List[float]
    dag: PlanDAG
    plan_status: str = "valid"

    @property
    def offload_rate(self) -> float:
        if not self.offload:
            return 0.0
        return float(np.mean(list(self.offload.values())))


class WorldModelExecutor:
    """Analytic executor backed by the seeded world model."""

    # executing without a needed input (dependency dropped or ignored by
    # SoT/PASTA-style schedulers) costs this factor per missing input —
    # milder than a *wrong* input (parent_penalty), matching the paper's
    # Table 1 pattern where SoT degrades CoT only moderately
    MISSING_DEP_PENALTY = 0.72

    def __init__(self, wm: WorldModel, cloud: bool, concurrency: int):
        self.wm = wm
        self.cloud = cloud
        self.concurrency = concurrency

    def run(self, query: Query, node: Node,
            dep_results: Dict[int, SubtaskResult]) -> SubtaskResult:
        st = _subtask_of(query, node)
        prof = self.wm.profile(int(self.cloud))
        p = prof.p_correct(st.difficulty)
        # penalties follow the query's GROUND-TRUTH information needs: a
        # planner/scheduler that drops an edge doesn't remove the need
        true_deps = st.deps
        n_bad = sum(1 for d in true_deps
                    if d in dep_results and not dep_results[d].correct)
        n_missing = sum(1 for d in true_deps if d not in dep_results)
        p *= self.wm.parent_penalty ** n_bad
        p *= self.MISSING_DEP_PENALTY ** n_missing
        u = self.wm._u(query, st.sid)
        # payload includes dependency answers (App. D.1): tok_in grows
        tok_in = st.tok_in + sum(dep_results[d].tok_out // 4
                                 for d in node.deps if d in dep_results)
        lat = prof.latency(tok_in, st.tok_out)
        cost = prof.cost(tok_in, st.tok_out)
        return SubtaskResult(st.sid, int(self.cloud), bool(u < p), lat, cost,
                             tok_in, st.tok_out,
                             answer=f"[{prof.name}] answer r{st.sid}")


def _subtask_of(query: Query, node: Node) -> Subtask:
    for st in query.subtasks:
        if st.sid == node.sid:
            return st
    # repaired/fallback plans may have synthesized filler nodes: derive one
    return Subtask(node.sid, node.desc, node.role, node.deps,
                   difficulty=0.5, tok_in=80, tok_out=120)


@dataclass
class Schedule:
    """Full event log of one query's execution (for Fig. 3 / traces)."""

    events: List[Tuple[float, float, int, int]] = field(default_factory=list)
    # (start, end, sid, routed_cloud)


def run_query(query: Query, dag: PlanDAG, policy: RoutingPolicy,
              edge: Executor, cloud: Executor, *, chain: bool = False,
              plan_status: str = "valid",
              schedule_out: Optional[Schedule] = None) -> QueryResult:
    """Execute one query's DAG. Returns QueryResult with simulated makespan."""
    order = topological_order(dag)
    if order is None:
        raise ValueError("scheduler requires a DAG (run repair first)")

    ctx = SchedulerContext()
    results: Dict[int, SubtaskResult] = {}
    offload: Dict[int, int] = {}
    indeg = {nd.sid: len(nd.deps) for nd in dag.nodes}
    children = {nd.sid: dag.children(nd.sid) for nd in dag.nodes}

    if chain:
        # sequential topological execution (HybridFlow-Chain): still routed,
        # but no concurrency — makespan is the plain sum
        t = 0.0
        for sid in order:
            node = dag.node(sid)
            ctx.extra["clock"] = t
            r, info = policy.decide(query, node, ctx)
            ex = cloud if r else edge
            res = ex.run(query, node, results)
            results[sid] = res
            offload[sid] = r
            ctx.k_used += res.api_cost
            ctx.l_used += res.latency
            ctx.position += 1
            policy.observe(query, node, r, res, ctx)
            if schedule_out is not None:
                schedule_out.events.append((t, t + res.latency, sid, r))
            t += res.latency
        final = results[order[-1]]
        gen = _generate_sid(dag, order)
        return QueryResult(query.qid, results[gen].correct, t,
                           sum(x.api_cost for x in results.values()),
                           results, offload, list(ctx.tau_trace), dag,
                           plan_status)

    # ---- event-driven concurrent execution ---------------------------
    clock = 0.0
    counter = itertools.count()
    busy = {id(edge): 0, id(cloud): 0}
    waiting: List[Tuple[int, Node]] = []       # ready but no free slot
    running: List[Tuple[float, int, int, Node, int, float]] = []  # heap
    ready = [dag.node(s) for s in order if indeg[s] == 0]

    def try_dispatch():
        nonlocal ready
        # route every ready subtask immediately (Algorithm 1 pops as soon
        # as dependencies resolve); dispatch respects worker concurrency
        for node in list(ready):
            ready.remove(node)
            ctx.extra["clock"] = clock
            r, info = policy.decide(query, node, ctx)
            offload[node.sid] = r
            ctx.position += 1
            waiting.append((r, node))
        for r, node in list(waiting):
            ex = cloud if r else edge
            if busy[id(ex)] < ex.concurrency:
                waiting.remove((r, node))
                busy[id(ex)] += 1
                res = ex.run(query, node, results)
                heapq.heappush(running, (clock + res.latency, next(counter),
                                         node.sid, node, r, clock))
                results[node.sid] = res  # provisional (fields are final)

    try_dispatch()
    while running:
        end, _, sid, node, r, start = heapq.heappop(running)
        clock = end
        ex = cloud if r else edge
        busy[id(ex)] -= 1
        res = results[sid]
        ctx.k_used += res.api_cost
        ctx.l_used += res.latency
        policy.observe(query, node, r, res, ctx)
        if schedule_out is not None:
            schedule_out.events.append((start, end, sid, r))
        for c in children[sid]:
            indeg[c] -= 1
            if indeg[c] == 0:
                ready.append(dag.node(c))
        try_dispatch()

    gen = _generate_sid(dag, order)
    return QueryResult(query.qid, results[gen].correct, clock,
                       sum(x.api_cost for x in results.values()),
                       results, offload, list(ctx.tau_trace), dag, plan_status)


def _generate_sid(dag: PlanDAG, order: List[int]) -> int:
    for nd in dag.nodes:
        if nd.role == "GENERATE":
            return nd.sid
    return order[-1]


def run_parallel_ignore_deps(query: Query, dag: PlanDAG, policy: RoutingPolicy,
                             edge: Executor, cloud: Executor) -> QueryResult:
    """SoT-style execution: every subtask launches at t=0 with no dependency
    context (missing-parent penalty applies). Used by baselines only."""
    ctx = SchedulerContext()
    results: Dict[int, SubtaskResult] = {}
    offload: Dict[int, int] = {}
    lat_pool: Dict[int, List[float]] = {}
    for nd in dag.nodes:
        r, _ = policy.decide(query, nd, ctx)
        ex = cloud if r else edge
        res = ex.run(query, nd, {})   # no dep results available
        results[nd.sid] = res
        offload[nd.sid] = r
        ctx.k_used += res.api_cost
        ctx.l_used += res.latency
        ctx.position += 1
        policy.observe(query, nd, r, res, ctx)
        lat_pool.setdefault(id(ex), []).append(res.latency)
    # makespan: per-pool serialization by concurrency
    makespan = 0.0
    pools = {id(edge): edge, id(cloud): cloud}
    for pid, lats in lat_pool.items():
        conc = max(pools[pid].concurrency, 1)
        # greedy LPT bound: sum/conc rounded with max item
        makespan = max(makespan, max(lats), sum(lats) / conc)
    gen = _generate_sid(dag, topological_order(dag) or [dag.nodes[-1].sid])
    return QueryResult(query.qid, results[gen].correct, makespan,
                       sum(x.api_cost for x in results.values()),
                       results, offload, list(ctx.tau_trace), dag)
