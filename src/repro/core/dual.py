"""Online dual thresholding (paper Eq. 10-11 / App. B.3, App. C Eq. 27).

Two equivalent parameterizations are provided:

  * ``DualController``   — shadow-price form: λ_{t+1}=[λ_t+η(C_used−C_max)]_+,
                           τ_t = clip(τ_0 + γ λ_t, 0, 1)        (Eq. 10-11)
  * ``TwoBudgetThreshold`` — the deployed two-resource form:
                           τ_t = clip(τ_0 + k_used/2K_max + l_used/2L_max, 0, 1)
                           (App. C Eq. 27; defaults τ_0=0.2, K_max=0.02,
                           L_max=20 exactly as the paper sets them)
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class DualController:
    eta: float = 0.5
    tau0: float = 0.2
    gamma: float = 1.0
    c_max: float = 0.5
    lam: float = 0.0

    def update(self, c_used: float) -> float:
        """Projected subgradient ascent on the dual (Eq. 10)."""
        self.lam = max(0.0, self.lam + self.eta * (c_used - self.c_max))
        return self.lam

    @property
    def tau(self) -> float:
        """Eq. 11."""
        return min(1.0, max(0.0, self.tau0 + self.gamma * self.lam))

    def step(self, c_used: float) -> float:
        self.update(c_used)
        return self.tau


@dataclass
class TwoBudgetThreshold:
    """App. C Eq. 27 — tracks (API $, latency s) budgets separately."""

    tau0: float = 0.2
    k_max: float = 0.02     # $ per query
    l_max: float = 20.0     # seconds per query
    k_used: float = 0.0
    l_used: float = 0.0

    def spend(self, dk: float = 0.0, dl: float = 0.0) -> None:
        self.k_used += dk
        self.l_used += dl

    @property
    def tau(self) -> float:
        t = (self.tau0 + self.k_used / (2 * self.k_max)
             + self.l_used / (2 * self.l_max))
        return min(1.0, max(0.0, t))

    @property
    def c_used(self) -> float:
        """Normalized cumulative cost (for the router's budget feature)."""
        return min(1.0, 0.5 * self.k_used / self.k_max
                   + 0.5 * self.l_used / self.l_max)

    def reset(self) -> None:
        self.k_used = 0.0
        self.l_used = 0.0
