"""Utility router f_θ: 2-hidden-layer MLP on (z_i, C_used) (paper Eq. 8).

Pure JAX; trained offline with AdamW + MSE against profiled utility
targets (Eq. 9 / Eq. 26). Checkpoints via repro.training.checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import embeddings as E
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclass(frozen=True)
class RouterConfig:
    in_dim: int = E.embedding_dim() + 1   # z_i ++ C_used(t)
    hidden: int = 128
    lr: float = 1e-4                      # paper: AdamW 1e-4
    weight_decay: float = 0.01
    epochs: int = 200
    batch: int = 256
    seed: int = 0


def init_router(cfg: RouterConfig, key=None):
    key = key if key is not None else jax.random.PRNGKey(cfg.seed)
    k1, k2, k3 = jax.random.split(key, 3)
    h = cfg.hidden

    def lin(k, i, o):
        return {"w": jax.random.normal(k, (i, o), jnp.float32) / np.sqrt(i),
                "b": jnp.zeros((o,), jnp.float32)}

    return {"l1": lin(k1, cfg.in_dim, h), "l2": lin(k2, h, h),
            "l3": lin(k3, h, 1)}


def router_apply(params, x):
    """x [n, in_dim] -> û ∈ (0,1) [n]  (Eq. 8: sigmoid(f_θ))."""
    h = jax.nn.relu(x @ params["l1"]["w"] + params["l1"]["b"])
    h = jax.nn.relu(h @ params["l2"]["w"] + params["l2"]["b"])
    out = h @ params["l3"]["w"] + params["l3"]["b"]
    return jax.nn.sigmoid(out[..., 0])


def make_features(z: np.ndarray, c_used: np.ndarray) -> np.ndarray:
    """Concatenate embeddings with the budget-state feature."""
    return np.concatenate([z, np.asarray(c_used, np.float32)[:, None]], axis=1)


@jax.jit
def _loss(params, x, y):
    pred = router_apply(params, x)
    return jnp.mean((pred - y) ** 2)


def train_router(cfg: RouterConfig, feats: np.ndarray, targets: np.ndarray,
                 *, log_every: int = 0) -> Tuple[Dict, list]:
    """Offline warm-start (Eq. 9): MSE regression to profiled utilities."""
    params = init_router(cfg)
    ocfg = AdamWConfig(lr=cfg.lr, weight_decay=cfg.weight_decay,
                       schedule="constant", grad_clip=1.0)
    opt = adamw_init(params)
    x = jnp.asarray(feats, jnp.float32)
    y = jnp.asarray(targets, jnp.float32)
    n = x.shape[0]
    rng = np.random.default_rng(cfg.seed)
    grad_fn = jax.jit(jax.value_and_grad(_loss))
    history = []
    for ep in range(cfg.epochs):
        perm = rng.permutation(n)
        tot = 0.0
        for i in range(0, n, cfg.batch):
            idx = perm[i:i + cfg.batch]
            lv, g = grad_fn(params, x[idx], y[idx])
            params, opt, _ = adamw_update(ocfg, g, opt, params)
            tot += float(lv) * len(idx)
        history.append(tot / n)
        if log_every and ep % log_every == 0:
            print(f"router epoch {ep}: mse {history[-1]:.5f}")
    return params, history


class Router:
    """Inference-side wrapper: embeds subtask descriptions and predicts û."""

    def __init__(self, params, cfg: Optional[RouterConfig] = None):
        self.params = params
        self.cfg = cfg or RouterConfig()
        self._apply = jax.jit(router_apply)

    def predict(self, descs: Sequence[str], c_used: float) -> np.ndarray:
        if not descs:
            return np.zeros(0, np.float32)
        z = E.embed_texts(list(descs))
        x = make_features(z, np.full(len(descs), c_used, np.float32))
        return np.asarray(self._apply(self.params, jnp.asarray(x)))

    def predict_one(self, desc: str, c_used: float) -> float:
        return float(self.predict([desc], c_used)[0])
