"""Privacy exposure proxy (paper App. D.1).

E_cloud  = Σ_{i∈C} tok(x_i)   — tokens transmitted in cloud payloads
Ē_cloud  = E_cloud / Σ_{i∈E∪C} tok(x_i)

where tok(x_i) counts the subtask description plus dependency answers
actually included in the request (SubtaskResult.tok_in).
"""
from __future__ import annotations

from typing import Iterable, Tuple

from repro.core.scheduler import QueryResult


def exposure(result: QueryResult) -> Tuple[int, float]:
    cloud_toks = sum(r.tok_in for r in result.results.values()
                     if r.routed_cloud)
    all_toks = sum(r.tok_in for r in result.results.values())
    return cloud_toks, (cloud_toks / all_toks if all_toks else 0.0)


def mean_exposure(results: Iterable[QueryResult]) -> Tuple[float, float]:
    es, ns = [], []
    for r in results:
        e, nbar = exposure(r)
        es.append(e)
        ns.append(nbar)
    if not es:
        return 0.0, 0.0
    return sum(es) / len(es), sum(ns) / len(ns)
