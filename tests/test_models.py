"""Per-architecture smoke tests (deliverable f): reduced variant of each
assigned architecture runs one forward + one train step on CPU with
correct shapes and no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.training.loop import make_train_step, init_train_state


def _batch(cfg, B=2, S=16, key=None):
    key = key or jax.random.PRNGKey(0)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jnp.ones(
            (B, cfg.n_image_patches, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_forward(arch):
    cfg = get_config(arch).reduced()
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.n_experts:
        assert cfg.n_experts <= 4
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = _batch(cfg)
    logits, aux = M.forward(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab())
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_train_step(arch):
    cfg = get_config(arch).reduced()
    params, opt = init_train_state(cfg, jax.random.PRNGKey(0),
                                   dtype=jnp.float32)
    step = jax.jit(make_train_step(cfg))
    batch = _batch(cfg)
    params, opt, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    # a second step must also be finite (optimizer state sanity)
    params, opt, metrics2 = step(params, opt, batch)
    assert np.isfinite(float(metrics2["loss"]))


def test_param_count_sanity():
    """Analytic parameter counts land near the advertised scales."""
    cases = {
        "mistral-large-123b": (110e9, 135e9),
        "mixtral-8x7b": (42e9, 52e9),
        "qwen2-1.5b": (1.2e9, 2.2e9),
        "internlm2-1.8b": (1.4e9, 2.4e9),
        "kimi-k2-1t-a32b": (0.8e12, 1.3e12),
    }
    for arch, (lo, hi) in cases.items():
        n = get_config(arch).param_count()
        assert lo <= n <= hi, (arch, n)


def test_moe_active_params():
    cfg = get_config("kimi-k2-1t-a32b")
    assert cfg.param_count(active_only=True) < 0.1 * cfg.param_count()


def test_vocab_padding():
    cfg = get_config("whisper-medium")
    assert cfg.padded_vocab() % 256 == 0
    assert cfg.padded_vocab() >= cfg.vocab_size
