"""Multi-query serving runtime: concurrent==sequential result equivalence,
global-budget exhaustion without deadlock, fair admission, KV-slot reuse,
order-stable same-tick completion observations, the ServingConfig surface
(incl. the legacy-kwarg deprecation shim), and open-loop timed admission
(arrivals / serve_trace) with TTFT and queue-wait reporting."""
import numpy as np
import pytest

from repro.core.dag import Node, PlanDAG
from repro.core.dual import TwoBudgetThreshold
from repro.core.hybridflow import (HybridFlowPolicy, Pipeline, RandomPolicy,
                                   StaticPolicy)
from repro.core.scheduler import (FleetScheduler, SubtaskResult,
                                  WorldModelExecutor, run_query)
from repro.data.tasks import Query, Subtask, WorldModel, gen_benchmark


def _planned(pipe, n=12, bench="gpqa"):
    qs = gen_benchmark(bench, n)
    return [(q, *pipe.plan(q)) for q in qs]


def _assert_same_result(a, b):
    assert a.qid == b.qid
    assert a.final_correct == b.final_correct
    assert a.offload == b.offload
    assert abs(a.api_cost - b.api_cost) < 1e-12
    assert set(a.results) == set(b.results)
    for sid in a.results:
        ra, rb = a.results[sid], b.results[sid]
        assert (ra.correct, ra.routed_cloud, ra.tok_in, ra.tok_out) == \
            (rb.correct, rb.routed_cloud, rb.tok_in, rb.tok_out)
        assert abs(ra.latency - rb.latency) < 1e-12


@pytest.mark.parametrize("policy_fn", [lambda: StaticPolicy(0),
                                       lambda: StaticPolicy(1),
                                       lambda: RandomPolicy(0.5)],
                         ids=["edge", "cloud", "random"])
def test_concurrent_matches_sequential_under_contention(policy_fn):
    """Timing-independent policies: every per-query result is identical
    whether N queries share the pools or run one at a time — slot
    contention shifts start times, never outcomes."""
    pipe = Pipeline()
    planned = _planned(pipe, 12)
    fleet = FleetScheduler(pipe.edge, pipe.cloud, max_inflight=8)
    pol = policy_fn()
    for q, dag, status in planned:
        fleet.submit(q, dag, pol, plan_status=status)
    conc = fleet.run()
    seq = [run_query(q, dag, policy_fn(), pipe.edge, pipe.cloud,
                     plan_status=status) for q, dag, status in planned]
    for a, b in zip(conc, seq):
        _assert_same_result(a, b)
    # pool sharing can only help the fleet: concurrent makespan is bounded
    # by running the same queries back-to-back
    assert fleet.makespan <= sum(r.latency for r in seq) + 1e-9


def test_concurrent_matches_sequential_hybridflow_wide_pools():
    """The full adaptive policy (clock-coupled duals) is equivalent too
    when pools are wide enough that queries never contend: each query's
    own event timeline is then exactly the isolated one."""
    from repro.core.profiler import train_default_router
    router, _ = train_default_router(n_queries=60, epochs=20)
    wm = WorldModel()
    edge = WorldModelExecutor(wm, cloud=False, concurrency=256)
    cloud = WorldModelExecutor(wm, cloud=True, concurrency=256)
    pipe = Pipeline(wm=wm)
    planned = _planned(pipe, 10)
    fleet = FleetScheduler(edge, cloud)
    pol_c = HybridFlowPolicy(router, wm=wm)
    for q, dag, status in planned:
        fleet.submit(q, dag, pol_c, plan_status=status)
    conc = fleet.run()
    pol_s = HybridFlowPolicy(router, wm=wm)   # fresh per-qid duals
    seq = [run_query(q, dag, pol_s, edge, cloud, plan_status=status)
           for q, dag, status in planned]
    for a, b in zip(conc, seq):
        _assert_same_result(a, b)
        assert np.allclose(a.tau_trace, b.tau_trace)
        assert abs(a.latency - b.latency) < 1e-12


def test_global_budget_exhaustion_no_deadlock():
    """Exhausting the fleet budget mid-flight forces edge routing but
    every query still completes (no subtask waits forever on the cloud)."""
    pipe = Pipeline()
    planned = _planned(pipe, 10)
    budget = TwoBudgetThreshold(tau0=0.0, k_max=0.002, l_max=float("inf"))
    fleet = FleetScheduler(pipe.edge, pipe.cloud, max_inflight=4,
                           global_budget=budget)
    pol = StaticPolicy(1)                     # policy wants cloud always
    for q, dag, status in planned:
        fleet.submit(q, dag, pol, plan_status=status)
    results = fleet.run()
    assert len(results) == 10
    assert all(r is not None and len(r.results) == r.dag.n for r in results)
    assert fleet.stats["forced_edge"] > 0
    assert budget.tau >= 1.0                  # budget really was exhausted
    # once exhausted, later subtasks ran (free) on the edge
    capped_cost = sum(r.api_cost for r in results)
    uncapped = FleetScheduler(pipe.edge, pipe.cloud, max_inflight=4)
    for q, dag, status in planned:
        uncapped.submit(q, dag, pol, plan_status=status)
    uncapped_cost = sum(r.api_cost for r in uncapped.run())
    assert capped_cost < uncapped_cost


def test_global_latency_budget_is_wall_clock():
    """The fleet latency budget is charged by clock advance, not by the
    per-subtask latency sum — N-way concurrency must not exhaust it N×
    faster. With l_max above the fleet makespan nothing is forced."""
    pipe = Pipeline()
    planned = _planned(pipe, 8)
    free = FleetScheduler(pipe.edge, pipe.cloud, max_inflight=8)
    pol = StaticPolicy(1)
    for q, dag, status in planned:
        free.submit(q, dag, pol, plan_status=status)
    baseline = free.run()
    lat_sum = sum(r.results[s].latency for r in baseline for s in r.results)
    assert lat_sum > free.makespan          # concurrency overlaps latencies

    budget = TwoBudgetThreshold(tau0=0.0, k_max=float("inf"),
                                l_max=free.makespan * 1.01 / 2)
    fleet = FleetScheduler(pipe.edge, pipe.cloud, max_inflight=8,
                           global_budget=budget)
    for q, dag, status in planned:
        fleet.submit(q, dag, pol, plan_status=status)
    results = fleet.run()
    assert fleet.stats["forced_edge"] == 0  # wall budget never exhausted
    assert abs(budget.l_used - fleet.makespan) < 1e-9
    assert len(results) == 8

    # a tight wall-clock cap does force edge, and still drains cleanly
    tight = TwoBudgetThreshold(tau0=0.0, k_max=float("inf"),
                               l_max=free.makespan * 0.1 / 2)
    fleet2 = FleetScheduler(pipe.edge, pipe.cloud, max_inflight=8,
                            global_budget=tight)
    for q, dag, status in planned:
        fleet2.submit(q, dag, pol, plan_status=status)
    assert len(fleet2.run()) == 8
    assert fleet2.stats["forced_edge"] > 0


def test_fair_admission_bounds_inflight():
    pipe = Pipeline()
    planned = _planned(pipe, 9)
    fleet = FleetScheduler(pipe.edge, pipe.cloud, max_inflight=3)
    pol = RandomPolicy(0.5)
    for q, dag, status in planned:
        fleet.submit(q, dag, pol, plan_status=status)
    results = fleet.run()
    assert len(results) == 9
    assert fleet.stats["peak_inflight"] == 3
    assert fleet.stats["dispatched"] == sum(r.dag.n for r in results)


def test_runtime_report_throughput_beats_sequential():
    """ServingRuntime end-to-end: >= 8 simultaneous queries through the
    HybridFlow scheduler at higher qps than one-query-at-a-time."""
    from repro.core.profiler import train_default_router
    from repro.serving.runtime import ServingRuntime
    router, _ = train_default_router(n_queries=60, epochs=20)
    pipe = Pipeline()
    qs = gen_benchmark("gpqa", 16)
    from repro.serving.runtime import ServingConfig
    rt_c = ServingRuntime(pipe.edge, pipe.cloud,
                          HybridFlowPolicy(router, wm=pipe.wm),
                          planner=pipe.planner,
                          config=ServingConfig(max_inflight=8))
    conc = rt_c.serve(qs)
    rt_s = ServingRuntime(pipe.edge, pipe.cloud,
                          HybridFlowPolicy(router, wm=pipe.wm),
                          planner=pipe.planner)
    seq = rt_s.serve(qs, mode="sequential")
    assert conc.stats["peak_inflight"] == 8
    assert conc.n == seq.n == 16
    assert conc.qps > seq.qps
    assert conc.makespan < seq.makespan
    assert conc.p99_latency >= conc.p50_latency > 0


def test_empty_batch_and_zero_budget():
    """Runtime edge cases: an empty batch reports cleanly, and a zero
    global cap means no cloud budget at all (exhausted before spend)."""
    from repro.serving.runtime import ServingConfig, ServingRuntime
    pipe = Pipeline()
    rt = ServingRuntime(pipe.edge, pipe.cloud, RandomPolicy(0.5),
                        planner=pipe.planner)
    for rep in (rt.serve([]), rt.serve([], mode="sequential")):
        assert rep.n == 0
        assert rep.qps == 0.0 and rep.p99_latency == 0.0
        assert "0 queries" in rep.summary()
    rt0 = ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(1),
                         planner=pipe.planner,
                         config=ServingConfig(global_k_max=0.0))
    rep = rt0.serve(gen_benchmark("gpqa", 3))
    assert rep.api_cost == 0.0
    assert rep.stats["forced_edge"] == sum(len(r.results)
                                           for r in rep.results)


class _InstantAsyncExecutor:
    """Async-surface executor that finishes every in-flight future on the
    next pump tick — many subtasks complete on the SAME tick, the exact
    condition under which observation order used to follow dispatch
    interleaving instead of a stable key."""

    def __init__(self, cloud, concurrency=64):
        self.cloud = cloud
        self.concurrency = concurrency
        self._open = []

    def submit(self, query, node, dep_results):
        h = {"node": node, "done": False}
        self._open.append(h)
        return h

    def pump(self):
        if not self._open:
            return False
        for h in self._open:
            h["done"] = True
        self._open.clear()
        return True

    def poll(self, h):
        if not h["done"]:
            return None
        return SubtaskResult(h["node"].sid, int(self.cloud), True, 0.01,
                             0.0, 10, 10, answer="x")


class _RecordingPolicy:
    def __init__(self):
        self.observed = []

    def decide(self, query, node, ctx):
        return 1, {}

    def observe(self, query, node, r, result, ctx):
        self.observed.append((query.qid, node.sid))


def _flat_query(qid, n=3):
    """n independent subtasks (no deps): all ready — and dispatched round-
    robin across queries — at t0."""
    sts = tuple(Subtask(i, f"{qid} part {i}", "ANALYZE", (), 0.5, 40, 60)
                for i in range(n))
    dag = PlanDAG(tuple(Node(s.sid, s.desc, s.role, s.deps) for s in sts))
    return Query(qid, "gpqa", f"flat query {qid}", sts), dag


def test_pumped_same_tick_completions_observed_in_sorted_order():
    """ROADMAP 'fleet-level policy state': a policy shared across the
    fleet (e.g. the HybridFlowPolicy LinUCB calibrator) must see
    same-tick completions in (qid, sid) order, not in engine-poll order
    — dispatch interleaves queries round-robin, so poll order would be
    timing- and replica-dependent."""
    pol = _RecordingPolicy()
    fleet = FleetScheduler(_InstantAsyncExecutor(False),
                           _InstantAsyncExecutor(True))
    # submit order deliberately unsorted by qid
    planned = [_flat_query(qid) for qid in ("q-c", "q-a", "q-b")]
    for q, dag in planned:
        fleet.submit(q, dag, pol)
    results = fleet.run()
    assert len(results) == 3
    assert fleet.stats["dispatched"] == 9
    # every subtask completed on one pump tick: the round-robin dispatch
    # order was (q-c 0, q-a 0, q-b 0, q-c 1, ...); observations must come
    # back fully sorted regardless
    assert len(pol.observed) == 9
    assert pol.observed == sorted(pol.observed)


def test_fleet_pump_overlaps_real_engines(model_zoo):
    """The async pump loop: subtasks from different queries decode in the
    same engine micro-batches (peak_active >= 2) and fleet results are
    identical to the sequential baseline — co-residency shifts timing,
    never outcomes (batch rows are independent)."""
    from repro.core.planner import SyntheticPlanner
    from repro.serving.engine import JAXExecutor, ServingEngine
    from repro.serving.runtime import ServingConfig, ServingRuntime
    cfg, params = model_zoo("qwen2-1.5b")
    wm = WorldModel()

    def build(pump):
        edge_e = ServingEngine(cfg, params, batch_slots=2, max_len=128)
        cloud_e = ServingEngine(cfg, params, batch_slots=4, max_len=128)
        edge = JAXExecutor(edge_e, wm, cloud=False, concurrency=1)
        cloud = JAXExecutor(cloud_e, wm, cloud=True, concurrency=4,
                            price_out=3.2e-5)
        rt = ServingRuntime(edge, cloud, StaticPolicy(1),
                            planner=SyntheticPlanner(),
                            config=ServingConfig(max_inflight=4, pump=pump))
        return rt, edge_e, cloud_e

    qs = gen_benchmark("gpqa", 4)
    rt_p, _, cloud_e = build(True)
    pumped = rt_p.serve(qs)
    rt_s, _, _ = build(False)
    seq = rt_s.serve(qs, mode="sequential")
    # real co-residency: >= 2 subtasks decoding in the same micro-batches
    assert cloud_e.stats["peak_active"] >= 2
    # no per-request full-cache prefill: every admitted request went
    # through the batched planner, >= 2 per call at the co-scheduled peak
    assert cloud_e.stats["prefill_calls"] > 0
    assert cloud_e.stats["prefill_batch_max"] >= 2
    assert pumped.n == seq.n == 4
    for a, b in zip(pumped.results, seq.results):
        assert a.qid == b.qid
        assert a.final_correct == b.final_correct
        assert a.offload == b.offload
        assert set(a.results) == set(b.results)
        for sid in a.results:
            ra, rb = a.results[sid], b.results[sid]
            assert (ra.correct, ra.routed_cloud, ra.tok_in, ra.tok_out,
                    ra.answer) == \
                (rb.correct, rb.routed_cloud, rb.tok_in, rb.tok_out,
                 rb.answer)


def test_kv_slots_reused_across_queries(model_zoo):
    """JAX engines under the fleet: many queries' subtasks lease the same
    bounded KV pool; slots are recycled, never grown."""
    from repro.core.planner import SyntheticPlanner
    from repro.serving.engine import JAXExecutor, ServingEngine
    from repro.serving.runtime import ServingConfig, ServingRuntime
    cfg, params = model_zoo("qwen2-1.5b")
    wm = WorldModel()
    engine = ServingEngine(cfg, params, batch_slots=2, max_len=128)
    edge = JAXExecutor(engine, wm, cloud=False, concurrency=1)
    cloud_engine = ServingEngine(cfg, params, batch_slots=2, max_len=128)
    cloud = JAXExecutor(cloud_engine, wm, cloud=True, concurrency=2,
                        price_out=3.2e-5)
    rt = ServingRuntime(edge, cloud, RandomPolicy(0.5),
                        planner=SyntheticPlanner(),
                        config=ServingConfig(max_inflight=4))
    report = rt.serve(gen_benchmark("gpqa", 4))
    assert report.n == 4
    n_subtasks = sum(len(r.results) for r in report.results)
    total_reqs = engine.stats["requests"] + cloud_engine.stats["requests"]
    assert total_reqs == n_subtasks
    # pool stayed bounded while serving more requests than slots exist
    for eng in (engine, cloud_engine):
        assert eng.stats["peak_active"] <= eng.slots
        if eng.stats["requests"] > eng.slots:
            assert eng.stats["slot_reuses"] >= eng.stats["requests"] - eng.slots


# ---- ServingConfig surface ---------------------------------------------

def test_serving_runtime_rejects_flat_kwargs():
    """The PR 8 deprecation shim is gone: the constructor surface is
    exactly (edge, cloud, policy, *, planner=, config=) and any other
    kwarg — including the formerly shimmed flat knobs — is a TypeError."""
    from repro.serving.runtime import ServingRuntime
    pipe = Pipeline()
    with pytest.raises(TypeError, match="unexpected keyword"):
        ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(0),
                       planner=pipe.planner, bogus_knob=1)
    with pytest.raises(TypeError, match="unexpected keyword"):
        ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(0),
                       planner=pipe.planner, max_inflight=3)


def test_serve_dispatcher_validation():
    from repro.serving.runtime import ServingRuntime
    pipe = Pipeline()
    rt = ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(0),
                        planner=pipe.planner)
    qs = gen_benchmark("gpqa", 2)
    with pytest.raises(ValueError, match="mode='fleet'"):
        rt.serve(qs, arrivals=[0.0, 0.0], mode="sequential")
    with pytest.raises(ValueError, match="unknown serve mode"):
        rt.serve(qs, mode="bogus")
    with pytest.raises(ValueError, match="arrival"):
        rt.serve(qs, arrivals=[0.0])          # length mismatch


# ---- open-loop timed admission -----------------------------------------

def test_open_loop_t0_is_bit_identical_to_closed_loop():
    """arrivals=[0]*n must take the exact legacy control flow: every
    per-query result and the fleet makespan match the closed loop."""
    from repro.serving.runtime import ServingConfig, ServingRuntime

    def run(arrivals):
        pipe = Pipeline()
        rt = ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(1),
                            planner=pipe.planner,
                            config=ServingConfig(max_inflight=4))
        qs = gen_benchmark("gpqa", 8)
        return rt.serve(qs) if arrivals is None \
            else rt.serve(qs, arrivals=arrivals)

    closed = run(None)
    open0 = run([0.0] * 8)
    assert open0.makespan == closed.makespan
    for a, b in zip(closed.results, open0.results):
        _assert_same_result(a, b)
        assert abs(a.latency - b.latency) < 1e-12
    # the open-loop run reports traffic metadata, the closed loop none
    assert closed.trace is None
    assert open0.trace is not None and open0.trace["n"] == 8
    assert all(r.arrival == 0.0 and r.queue_wait >= 0.0
               for r in open0.results)


def test_open_loop_staggered_arrivals_gate_admission():
    """Queries cannot start before they arrive: completion time >= its
    arrival + work, TTFT/queue percentiles populate, and a wide-open
    fleet admits each query exactly at its arrival (zero queue wait)."""
    from repro.serving.runtime import ServingConfig, ServingRuntime
    pipe = Pipeline()
    rt = ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(0),
                        planner=pipe.planner,
                        config=ServingConfig(max_inflight=None))
    qs = gen_benchmark("gpqa", 5)
    arrivals = [0.0, 2.0, 4.0, 6.0, 8.0]
    rep = rt.serve(qs, arrivals=arrivals)
    assert rep.n == 5
    for r, t in zip(rep.results, arrivals):
        assert r.arrival == t
        assert r.ttft > 0.0
        assert r.queue_wait < 1e-9        # nothing to wait on
    assert rep.p99_ttft >= rep.p50_ttft > 0.0
    assert rep.trace["offered_rps"] > 0
    # arrivals stretch the fleet window beyond the closed-loop makespan
    assert rep.makespan >= 8.0
    assert "offered" in rep.summary() and "ttft" in rep.summary()


def test_open_loop_overload_queues_late_queries():
    """A 1-inflight fleet with simultaneous late arrivals: later queries
    wait their turn — queue_wait grows monotonically along the backlog."""
    from repro.serving.runtime import ServingConfig, ServingRuntime
    pipe = Pipeline()
    rt = ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(0),
                        planner=pipe.planner,
                        config=ServingConfig(max_inflight=1))
    qs = gen_benchmark("gpqa", 4)
    rep = rt.serve(qs, arrivals=[0.0, 0.1, 0.1, 0.1])
    waits = [r.queue_wait for r in rep.results]
    assert waits[1] < waits[2] < waits[3]
    assert all(r.ttft >= r.queue_wait for r in rep.results)


def test_serve_trace_end_to_end_with_real_engines(model_zoo):
    """serve_trace through the pumped driver and real JAX engines: timed
    admission holds queries back on the wall clock and every query still
    completes with populated TTFT."""
    from repro.core.planner import SyntheticPlanner
    from repro.serving.engine import JAXExecutor, ServingEngine
    from repro.serving.runtime import ServingConfig, ServingRuntime
    from repro.serving.traffic import Trace
    cfg, params = model_zoo("qwen2-1.5b")
    wm = WorldModel()
    edge = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                     max_len=128),
                       wm, cloud=False, concurrency=1)
    cloud = JAXExecutor(ServingEngine(cfg, params, batch_slots=4,
                                      max_len=128),
                        wm, cloud=True, price_out=3.2e-5)
    rt = ServingRuntime(edge, cloud, StaticPolicy(1),
                        planner=SyntheticPlanner(),
                        config=ServingConfig(max_inflight=4, pump=True))
    trace = Trace(arrivals=(0.0, 0.3, 0.6), duration=1.0, label="tiny")
    rep = rt.serve_trace(trace, gen_benchmark("gpqa", 3))
    assert rep.n == 3
    assert all(r is not None and len(r.results) == r.dag.n
               for r in rep.results)
    for r, t in zip(rep.results, trace.arrivals):
        assert r.arrival == t
        assert r.ttft > 0.0
    assert rep.trace["label"] == "tiny"
    assert rep.trace["offered_rps"] == pytest.approx(3.0)
