"""Training substrate: optimizer math, LM loss descent, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data.pipeline import packed_batches, Prefetcher
from repro.training.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                      schedule_lr, clip_by_global_norm,
                                      global_norm)
from repro.training.loop import train, TrainConfig
from repro.training import checkpoint as CKPT


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant",
                      grad_clip=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_grad_clip():
    g = {"a": jnp.ones((10,)) * 10.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_schedule_warmup_cosine():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100)
    lr0 = float(schedule_lr(cfg, jnp.asarray(1)))
    lr_w = float(schedule_lr(cfg, jnp.asarray(10)))
    lr_end = float(schedule_lr(cfg, jnp.asarray(100)))
    assert lr0 < lr_w
    assert lr_end < lr_w
    assert lr_end >= cfg.lr * cfg.min_lr_frac * 0.99


def test_lm_loss_decreases():
    """A tiny model on the synthetic Zipf stream must learn (loss drops)."""
    cfg = get_config("qwen2-1.5b").reduced().variant(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        d_ff=128, vocab_size=512)
    data = packed_batches(batch=8, seq_len=64, seed=0, vocab_limit=512)
    data = ({k: jnp.asarray(v) for k, v in b.items()} for b in data)
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3, schedule="constant",
                                       warmup_steps=0), log_every=100)
    _, _, hist = train(cfg, data, steps=60, tcfg=tcfg,
                       log_fn=lambda s: None)
    assert hist[-1]["loss"] < hist[0]["loss"] - 0.5, hist


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("internlm2-1.8b").reduced()
    from repro.models import model as M
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    opt = adamw_init(params)
    path = os.path.join(tmp_path, "ckpt_1")
    CKPT.save_checkpoint(path, {"params": params, "opt": opt}, step=17)
    template = {"params": jax.tree.map(jnp.zeros_like, params),
                "opt": jax.tree.map(jnp.zeros_like, opt)}
    restored, step = CKPT.restore_checkpoint(path, template)
    assert step == 17
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_structure_mismatch(tmp_path):
    import pytest
    CKPT.save_checkpoint(os.path.join(tmp_path, "c"), {"a": jnp.ones(3)})
    with pytest.raises(ValueError):
        CKPT.restore_checkpoint(os.path.join(tmp_path, "c"),
                                {"b": jnp.ones(3)})


def test_prefetcher():
    it = Prefetcher(iter(range(100)), depth=4)
    assert list(it) == list(range(100))
