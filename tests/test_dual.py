"""Online dual thresholding (Eq. 10-11 / Eq. 27)."""
from _prop import given, settings, st

from repro.core.dual import DualController, TwoBudgetThreshold


def test_lambda_increases_when_overspent():
    d = DualController(eta=0.5, c_max=0.3)
    lam0 = d.lam
    d.update(c_used=0.8)
    assert d.lam > lam0


def test_lambda_projected_nonnegative():
    d = DualController(eta=0.5, c_max=0.9, lam=0.1)
    d.update(c_used=0.0)
    assert d.lam >= 0.0


def test_tau_clipped():
    d = DualController(tau0=0.9, gamma=10.0, lam=5.0)
    assert d.tau == 1.0


def test_two_budget_eq27():
    t = TwoBudgetThreshold(tau0=0.2, k_max=0.02, l_max=20.0)
    t.spend(dk=0.01, dl=5.0)
    # tau = 0.2 + 0.01/0.04 + 5/40 = 0.575
    assert abs(t.tau - 0.575) < 1e-9


def test_threshold_monotone_in_spend():
    t = TwoBudgetThreshold()
    taus = [t.tau]
    for _ in range(10):
        t.spend(dk=0.002, dl=1.0)
        taus.append(t.tau)
    assert all(b >= a for a, b in zip(taus, taus[1:]))
    assert taus[-1] <= 1.0


@settings(max_examples=60, deadline=None)
@given(st.lists(st.floats(0.0, 0.3), min_size=1, max_size=40),
       st.floats(0.05, 0.5), st.floats(0.05, 2.0))
def test_dual_ascent_budget_compliance(costs, c_max, eta):
    """Property: with the dual update, cumulative overspend pressure makes
    λ grow at least linearly in the excess (projected subgradient)."""
    d = DualController(eta=eta, c_max=c_max)
    c_used = 0.0
    for c in costs:
        c_used += c
        d.update(c_used)
        assert d.lam >= 0.0
    if c_used > c_max:
        assert d.lam >= eta * (c_used - c_max) - 1e-9
    assert 0.0 <= d.tau <= 1.0
