"""shard_map expert-parallel MoE (§Perf #1): forward + gradient parity
with the dense oracle on a real 8-device host mesh.

Runs in a subprocess because the XLA device count must be fixed before
jax initializes.
"""
import os
import subprocess
import sys


SRC = os.path.join(os.path.dirname(__file__), "..", "src")

_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.configs import get_config
from repro.models import moe as MoE
from repro.distributed.context import mesh_context

cfg = get_config("mixtral-8x7b").reduced().variant(capacity_factor=8.0,
                                                   moe_impl="ep")
p = MoE.init_moe(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model))

def dense_loss(p, x):
    y, aux = MoE.moe_forward_dense(p, cfg, x)
    return jnp.sum(y ** 2) + aux

g_dense = jax.grad(dense_loss)(p, x)
y_dense, aux_dense = MoE.moe_forward_dense(p, cfg, x)

for shape in ((2, 4), (4, 2), (1, 8)):
    mesh = jax.make_mesh(shape, ("data", "model"))

    def ep_loss(p, x):
        with mesh_context(mesh):
            y, aux = MoE.moe_forward(p, cfg, x)
        return jnp.sum(y ** 2) + aux

    with mesh_context(mesh), mesh:
        y_ep, aux_ep = jax.jit(
            lambda p, x: MoE.moe_forward(p, cfg, x))(p, x)
        g_ep = jax.jit(jax.grad(ep_loss))(p, x)
    fwd_err = float(jnp.max(jnp.abs(y_ep - y_dense)))
    aux_err = float(abs(aux_ep - aux_dense))
    grad_err = max(
        float(jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-9))
        for a, b in zip(jax.tree.leaves(g_ep), jax.tree.leaves(g_dense)))
    assert fwd_err < 1e-4, (shape, fwd_err)
    # f32 sum-order noise: the aux (load-balance) loss sums per-expert
    # fractions in device order, which differs per mesh shape — observed
    # up to ~6e-4 on the (4, 2) host mesh; a real parity bug is >1e-1
    assert aux_err < 1e-3, (shape, aux_err)
    assert grad_err < 1e-4, (shape, grad_err)
    print(f"mesh {shape}: fwd {fwd_err:.2e} aux {aux_err:.2e} "
          f"grad {grad_err:.2e} OK")
print("ALL_OK")
"""


def test_ep_moe_matches_dense_oracle():
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert "ALL_OK" in out.stdout
