"""Arrival-trace generator: seeded determinism, rate fidelity, phase
ramps, burst/gap shapes, wall-clock scaling, JSON replay round-trip."""
import json

import pytest

from repro.serving.traffic import Phase, Trace, day_cycle


def test_poisson_deterministic_and_rate_accurate():
    """Same seed → identical arrivals; long-horizon mean RPS within 5%
    of target (the same invariant check_bench gates in CI)."""
    a = Trace.poisson(4.0, 600.0, seed=7)
    b = Trace.poisson(4.0, 600.0, seed=7)
    assert a.arrivals == b.arrivals
    assert a.n == len(a) > 0
    assert abs(a.mean_rps - 4.0) / 4.0 < 0.05
    assert all(0.0 <= t <= 600.0 for t in a.arrivals)
    assert list(a.arrivals) == sorted(a.arrivals)
    # different seed → different draw
    assert Trace.poisson(4.0, 600.0, seed=8).arrivals != a.arrivals


def test_phase_ramp_rates_and_validation():
    ph = Phase(duration=10.0, rps=1.0, rps_end=3.0)
    assert ph.rate_at(0.0) == 1.0
    assert ph.rate_at(5.0) == pytest.approx(2.0)
    assert ph.peak == 3.0
    assert ph.mean_rps == pytest.approx(2.0)
    with pytest.raises(ValueError):
        Phase(duration=0.0, rps=1.0)
    with pytest.raises(ValueError):
        Phase(duration=1.0, rps=-0.5)


def test_day_cycle_peak_density():
    """The day-cycle trace concentrates arrivals in its peak phase:
    per-second density at the peak beats the trough by the rps ratio's
    order of magnitude."""
    phases = day_cycle(base_rps=0.5, peak_rps=4.0, duration=1000.0)
    assert sum(p.duration for p in phases) == pytest.approx(1000.0)
    tr = Trace.from_phases(phases, seed=11)
    trough_end = phases[0].duration
    peak_start = phases[0].duration + phases[1].duration
    peak_end = peak_start + phases[2].duration
    trough = sum(1 for t in tr.arrivals if t < trough_end) / trough_end
    peak = sum(1 for t in tr.arrivals
               if peak_start <= t < peak_end) / phases[2].duration
    assert peak > 2 * trough


def test_bursty_gap_is_empty_and_burst_is_dense():
    tr = Trace.bursty(base_rps=0.5, duration=100.0, burst_rps=8.0,
                      burst_at=20.0, burst_s=5.0, gap_at=50.0, gap_s=30.0,
                      seed=5)
    assert not [t for t in tr.arrivals if 50.0 <= t < 80.0]
    assert tr.largest_gap() >= 30.0
    burst = [t for t in tr.arrivals if 20.0 <= t < 25.0]
    assert len(burst) / 5.0 > 2 * 0.5   # well above base rate


def test_scaled_compresses_wall_clock_not_counts():
    tr = Trace.bursty(base_rps=0.2, duration=60.0, burst_rps=1.0,
                      burst_at=10.0, burst_s=5.0, seed=3)
    half = tr.scaled(0.5)
    assert half.n == tr.n
    assert half.duration == pytest.approx(30.0)
    assert half.arrivals == tuple(pytest.approx(t * 0.5)
                                  for t in tr.arrivals)
    assert half.mean_rps == pytest.approx(2 * tr.mean_rps)
    assert half.target_rps == pytest.approx(2 * tr.target_rps)
    assert half.label.endswith("@x0.5")


def test_json_round_trip_replays_identically(tmp_path):
    tr = Trace.poisson(2.0, 30.0, seed=1, label="rt")
    path = tmp_path / "trace.json"
    tr.to_json(path)
    back = Trace.from_json(path)
    assert back.arrivals == tr.arrivals
    assert (back.duration, back.seed, back.label) == (30.0, 1, "rt")
    assert back.target_rps == tr.target_rps
    # string form round-trips too, and the full value (incl. phase
    # metadata) survives — Trace is a frozen dataclass so == is exact
    again = Trace.from_json(tr.to_json())
    assert again == tr
    assert again.phases == tr.phases != ()
    assert json.loads(tr.to_json())["label"] == "rt"


def test_trace_validation():
    with pytest.raises(ValueError):
        Trace.poisson(-1.0, 10.0, seed=0)
    with pytest.raises(ValueError):
        Trace.poisson(1.0, 0.0, seed=0)
    with pytest.raises(ValueError):
        Trace(arrivals=(1.0,), duration=10.0).scaled(0.0)
    # unsorted input is normalised, never rejected
    tr = Trace(arrivals=(3.0, 1.0, 2.0), duration=5.0)
    assert tr.arrivals == (1.0, 2.0, 3.0)
