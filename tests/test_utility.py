"""Utility model + knapsack oracle (paper §3.1, App. B)."""
import numpy as np
from _prop import given, settings, st

from repro.core.utility import (normalized_cost, utility, knapsack_oracle,
                                greedy_ratio, lagrangian_policy, EPS)


def test_normalized_cost_eq24():
    # App. C Eq. 24: scales 10 s and $0.02
    assert abs(normalized_cost(5.0, 0.01) - (0.25 + 0.25)) < 1e-9
    assert normalized_cost(100.0, 1.0) == 1.0   # clipped
    assert normalized_cost(0.0, 0.0) == 0.0


def test_utility_clip():
    assert utility(0.5, 0.1) == 1.0          # clipped at 1
    assert utility(-0.2, 0.5) == 0.0         # clipped at 0
    assert abs(utility(0.05, 0.5) - 0.05 / (0.5 + EPS)) < 1e-9


def test_knapsack_simple():
    dq = [0.5, 0.4, 0.3]
    c = [0.5, 0.3, 0.3]
    r, val = knapsack_oracle(dq, c, budget=0.6)
    assert abs(val - 0.7) < 1e-9            # items 1+2
    assert list(r) == [0, 1, 1]


def test_knapsack_respects_budget():
    rng = np.random.default_rng(0)
    dq = rng.uniform(0, 0.3, 12)
    c = rng.uniform(0.05, 0.5, 12)
    r, _ = knapsack_oracle(dq, c, budget=0.8)
    # floor discretization: overshoot bounded by n/grid
    assert float(np.sum(c * r)) <= 0.8 + 12 / 1000 + 1e-9


def test_lagrangian_threshold_structure():
    dq = np.array([0.3, 0.1, 0.02])
    c = np.array([0.2, 0.2, 0.2])
    r = lagrangian_policy(dq, c, lam=0.6)
    # offload iff dq/c > λ: ratios 1.5, 0.5, 0.1
    assert list(r) == [1, 0, 0]


@settings(max_examples=80, deadline=None)
@given(st.integers(1, 10), st.floats(0.1, 1.5), st.integers(0, 10_000))
def test_knapsack_dominates_greedy(n, budget, seed):
    """The DP oracle is an upper bound on the greedy ratio heuristic."""
    rng = np.random.default_rng(seed)
    dq = rng.uniform(0, 0.4, n)
    c = rng.uniform(0.02, 0.6, n)
    r_dp, v_dp = knapsack_oracle(dq, c, budget)
    r_g = greedy_ratio(dq, c, budget)
    v_g = float(np.sum(dq * r_g))
    # floor discretization makes the DP an upper bound on any feasible
    # allocation, greedy included
    assert v_dp >= v_g - 1e-6
    assert float(np.sum(c * r_dp)) <= budget + n / 1000 + 1e-9


@settings(max_examples=50, deadline=None)
@given(st.integers(2, 8), st.integers(0, 10_000))
def test_lagrangian_sweep_traces_knapsack_frontier(n, seed):
    """As λ decreases, the threshold policy offloads monotonically more."""
    rng = np.random.default_rng(seed)
    dq = rng.uniform(0, 0.4, n)
    c = rng.uniform(0.05, 0.6, n)
    prev = None
    for lam in (2.0, 1.0, 0.5, 0.1, 0.0):
        r = set(np.nonzero(lagrangian_policy(dq, c, lam))[0].tolist())
        if prev is not None:
            assert prev <= r
        prev = r
