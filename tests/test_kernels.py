"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + properties."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels import ops, ref


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-3


# ---- flash attention ------------------------------------------------------

FLASH_CASES = [
    # (B, Sq, Sk, H, KV, hd, causal, window)
    (2, 64, 64, 4, 2, 32, True, None),
    (1, 128, 128, 8, 8, 64, True, None),
    (2, 96, 96, 4, 1, 32, True, 32),      # SWA + max GQA
    (1, 37, 80, 2, 2, 16, False, None),   # ragged cross-attn
    (1, 200, 200, 2, 1, 128, True, 64),   # hd=128 MXU tile
]


@pytest.mark.parametrize("case", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(case, dtype):
    B, Sq, Sk, H, KV, hd, causal, window = case
    # crc32, not hash(): tuples holding None hash process-randomized < 3.12
    ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(repr(case).encode())), 3)
    q = _rand(ks[0], (B, Sq, H, hd), dtype)
    k = _rand(ks[1], (B, Sk, KV, hd), dtype)
    v = _rand(ks[2], (B, Sk, KV, hd), dtype)
    off = Sk - Sq if causal else 0
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              q_offset=off, bq=32, bk=32)
    want = ref.attention_ref(q, k, v, causal=causal, window=window,
                             q_offset=off)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 3), st.integers(8, 70), st.integers(1, 4),
       st.sampled_from([16, 32]), st.booleans(), st.integers(0, 10_000))
def test_flash_attention_property(B, S, KV, hd, causal, seed):
    """Property: kernel == oracle for arbitrary shapes incl. non-multiples."""
    H = KV * 2
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = _rand(ks[0], (B, S, H, hd), jnp.float32)
    k = _rand(ks[1], (B, S, KV, hd), jnp.float32)
    v = _rand(ks[2], (B, S, KV, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, bq=16, bk=16)
    want = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_flash_attention_softmax_invariance():
    """Scaling all scores by adding a constant to q·k via key shift must not
    change softmax output materially (online-softmax stability)."""
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(ks[0], (1, 32, 2, 16), jnp.float32)
    k = _rand(ks[1], (1, 32, 2, 16), jnp.float32)
    v = _rand(ks[2], (1, 32, 2, 16), jnp.float32)
    o1 = ops.flash_attention(q, k, v, bq=16, bk=16)
    o2 = ops.flash_attention(q * 4.0, k, v, bq=16, bk=16)  # sharp softmax
    assert np.isfinite(np.asarray(o2)).all()
    assert not np.allclose(np.asarray(o1), np.asarray(o2))


# ---- decode attention -----------------------------------------------------

DECODE_CASES = [
    (2, 4, 2, 32, 96),
    (3, 8, 8, 64, 130),
    (1, 2, 1, 128, 512),
    (4, 12, 2, 128, 64),
]


@pytest.mark.parametrize("case", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_sweep(case, dtype):
    B, H, KV, hd, M = case
    ks = jax.random.split(jax.random.PRNGKey(zlib.crc32(repr(case).encode())), 3)
    q = _rand(ks[0], (B, 1, H, hd), dtype)
    k = _rand(ks[1], (B, M, KV, hd), dtype)
    v = _rand(ks[2], (B, M, KV, hd), dtype)
    kv_len = jnp.asarray([max(1, M - 7 * i) for i in range(B)], jnp.int32)
    out = ops.decode_attention(q, k, v, kv_len=kv_len, bk=32)
    want = ref.decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(4, 80), st.integers(1, 10_000))
def test_decode_attention_property(B, M, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = _rand(ks[0], (B, 1, 4, 32), jnp.float32)
    k = _rand(ks[1], (B, M, 2, 32), jnp.float32)
    v = _rand(ks[2], (B, M, 2, 32), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, M + 1)
    out = ops.decode_attention(q, k, v, kv_len=kv_len, bk=16)
    want = ref.decode_attention_ref(q, k, v, kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


# ---- rmsnorm --------------------------------------------------------------

@pytest.mark.parametrize("shape", [(8, 64), (100, 128), (3, 7, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 2)
    x = _rand(ks[0], shape, dtype)
    s = _rand(ks[1], shape[-1:], jnp.float32)
    out = ops.rmsnorm(x, s)
    want = ref.rmsnorm_ref(x, s)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_model_path_with_pallas_enabled():
    """End-to-end: enabling the Pallas dispatch reproduces the jnp model."""
    from repro.kernels.dispatch import pallas_enabled
    from repro.configs import get_config
    from repro.models import model as M
    cfg = get_config("internlm2-1.8b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(2, 16) % cfg.vocab_size}
    ref_logits, _ = M.forward(params, cfg, batch)
    with pallas_enabled(True):
        pl_logits, _ = M.forward(params, cfg, batch)
    np.testing.assert_allclose(np.asarray(pl_logits), np.asarray(ref_logits),
                               atol=5e-3, rtol=5e-3)
