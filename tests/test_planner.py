"""EAG planner: XML round-trip, tolerant parsing, Table 5 statistics."""
from collections import Counter

import numpy as np
import pytest
from _prop import given, settings, st

from repro.core.dag import validate, compression_ratio
from repro.core.planner import SyntheticPlanner, parse_plan, plan_to_xml
from repro.data.tasks import gen_benchmark


def test_xml_roundtrip():
    pl = SyntheticPlanner()
    q = gen_benchmark("gpqa", 1)[0]
    dag = pl.true_dag(q)
    parsed = parse_plan(plan_to_xml(dag))
    assert parsed.n == dag.n
    assert {n.sid: n.deps for n in parsed.nodes} == \
        {n.sid: n.deps for n in dag.nodes}
    assert {n.sid: n.role for n in parsed.nodes} == \
        {n.sid: n.role for n in dag.nodes}


def test_parse_tolerates_prose():
    xml = ('Sure! Here is the plan:\n<Plan>\n'
           '<Step ID="1" Task="Explain: what is asked" Rely=""/>\n'
           '<Step ID="2" Task="Generate: answer" Rely="1"/>\n'
           '</Plan>\nHope that helps!')
    d = parse_plan(xml)
    assert d.n == 2
    assert d.node(1).deps == (0,)


def test_parse_truncated_xml_regex_fallback():
    xml = ('<Plan>\n<Step ID="1" Task="Explain: x" Rely=""/>\n'
           '<Step ID="2" Task="Generate: y" Rely="1"/>\n')  # no </Plan>
    d = parse_plan(xml)
    assert d.n == 2


def test_parse_garbage_raises():
    with pytest.raises(ValueError):
        parse_plan("no plan here at all")


def test_table5_statistics():
    """Paper Table 5: valid 76-78%, repaired 13-14%, fallback 9-10%."""
    qs = gen_benchmark("gpqa", 400)
    pl = SyntheticPlanner()
    stats = Counter()
    for q in qs:
        dag, status = pl.plan(q)
        assert validate(dag).ok
        stats[status] += 1
    tot = sum(stats.values())
    assert 0.65 <= stats["valid"] / tot <= 0.90
    assert 0.05 <= stats["repaired"] / tot <= 0.25
    assert 0.03 <= stats["fallback"] / tot <= 0.20


def test_plans_expose_parallelism():
    """R_comp > 0 on average (paper Table 7: DAGs beat chains)."""
    qs = gen_benchmark("gpqa", 100)
    pl = SyntheticPlanner()
    rc = [compression_ratio(pl.plan(q)[0]) for q in qs]
    assert np.mean(rc) > 0.1


@settings(max_examples=60, deadline=None)
@given(st.text(max_size=200))
def test_parse_never_crashes_unexpectedly(text):
    """Fuzz: parser either returns a PlanDAG or raises ValueError."""
    try:
        d = parse_plan(text)
        assert d.n >= 1
    except ValueError:
        pass
