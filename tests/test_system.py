"""End-to-end behaviour tests for the paper's system (Table 1-3 claims,
scaled down for CI speed)."""
import numpy as np
import pytest

from repro.core.hybridflow import Pipeline
from repro.core.profiler import train_default_router
from repro.core.exposure import mean_exposure
from repro.core.utility import UnifiedMetric
from repro.data.tasks import gen_benchmark


@pytest.fixture(scope="module")
def router():
    r, info = train_default_router(n_queries=150, epochs=60)
    assert info["final_mse"] < 0.08
    return r


@pytest.fixture(scope="module")
def pipe():
    return Pipeline()


@pytest.fixture(scope="module")
def queries():
    return gen_benchmark("gpqa", 120)


def test_edge_cloud_ordering(pipe, queries):
    e = pipe.cot(queries, "edge")
    c = pipe.cot(queries, "cloud")
    assert c.accuracy > e.accuracy + 0.15
    assert c.api_cost > 0 and e.api_cost == 0
    assert c.latency > e.latency          # slow API cloud (per paper)


def test_decomposition_beats_direct(pipe, queries):
    """Paper claim: structured decomposition beats direct prompting."""
    for model in ("edge", "cloud"):
        d = pipe.direct(queries, model)
        c = pipe.cot(queries, model)
        assert c.accuracy > d.accuracy - 0.02


def test_hybridflow_beats_ablation_arms_on_utility(pipe, queries, router):
    """Paper Table 3: HybridFlow attains the highest unified utility."""
    e = pipe.cot(queries, "edge")

    def u(m):
        um = UnifiedMetric(m.accuracy, m.latency, m.api_cost)
        c = um.normalized_cost(edge_latency=e.latency)
        if c < 0.02:
            return float("nan")
        return um.utility(e.accuracy, e.latency)

    hf = pipe.hybridflow(queries, router)
    u_hf = u(hf)
    u_cloud = u(pipe.cot(queries, "cloud"))
    u_rand = u(pipe.random(queries))
    u_chain = u(pipe.hybridflow(queries, router, chain=True))
    assert u_hf > u_cloud, (u_hf, u_cloud)
    assert u_hf > u_rand, (u_hf, u_rand)
    assert u_hf > u_chain, (u_hf, u_chain)
    fixed_us = [u(pipe.fixed(queries, router, t))
                for t in (0.3, 0.4, 0.5, 0.6)]
    assert u_hf > np.nanmax(fixed_us), (u_hf, fixed_us)


def test_parallelism_reduces_latency(pipe, queries, router):
    """Paper Table 3: HybridFlow-Chain is slower than HybridFlow."""
    hf = pipe.hybridflow(queries, router)
    ch = pipe.hybridflow(queries, router, chain=True)
    assert hf.latency < ch.latency


def test_adaptive_threshold_rises_within_query(pipe, queries, router):
    """Fig. 3: the adaptive threshold increases with subtask position."""
    hf = pipe.hybridflow(queries, router)
    rising = 0
    tot = 0
    for r in hf.results:
        if len(r.tau_trace) >= 3:
            tot += 1
            if r.tau_trace[-1] > r.tau_trace[0]:
                rising += 1
    assert rising / max(tot, 1) > 0.9


def test_exposure_reduced_vs_cloud_only(pipe, queries, router):
    """App. D.1: HybridFlow transmits fewer tokens than cloud-only."""
    hf = pipe.hybridflow(queries, router)
    cl = pipe.cot(queries, "cloud")
    e_hf, n_hf = mean_exposure(hf.results)
    e_cl, n_cl = mean_exposure(cl.results)
    assert e_hf < e_cl
    assert n_hf < n_cl == 1.0


def test_bandit_calibration_no_collapse(pipe, queries, router):
    """Enabling LinUCB keeps the system in a sane operating band."""
    hf = pipe.hybridflow(queries[:60], router, calibrate=True)
    assert 0.05 < hf.offload_rate < 0.95
    assert hf.accuracy > 0.25
