"""LinUCB calibration head (Eq. 13-14)."""
import numpy as np

from repro.core.bandit import LinUCBCalibrator, reward


def test_reward_eq14():
    assert reward(0.3, 0.5, 0.2) == 0.3 - 0.5 * 0.2


def test_warm_start_identity():
    cal = LinUCBCalibrator(dim=2)
    # prior θ0 = e1 -> ũ == û before any update
    for u in (0.1, 0.5, 0.9):
        assert abs(cal.calibrated(u, [0.0, 0.0]) - u) < 1e-9


def test_ucb_exceeds_point_estimate():
    cal = LinUCBCalibrator(dim=1, alpha_ucb=0.5)
    assert cal.ucb(0.4, [0.2]) >= cal.calibrated(0.4, [0.2])


def test_learns_linear_shift():
    """True reward = 0.5·û + 0.2 + 0.3·s: after updates the calibrated
    estimate tracks it much better than the uncalibrated û."""
    rng = np.random.default_rng(0)
    cal = LinUCBCalibrator(dim=1, ridge=1.0)
    for _ in range(400):
        u = rng.uniform(0, 1)
        s = rng.uniform(-1, 1)
        r = 0.5 * u + 0.2 + 0.3 * s + rng.normal(0, 0.01)
        cal.update(u, [s], r)
    errs_cal, errs_raw = [], []
    for _ in range(100):
        u = rng.uniform(0, 1)
        s = rng.uniform(-1, 1)
        true = np.clip(0.5 * u + 0.2 + 0.3 * s, 0, 1)
        errs_cal.append(abs(cal.calibrated(u, [s]) - true))
        errs_raw.append(abs(u - true))
    assert np.mean(errs_cal) < 0.05
    assert np.mean(errs_cal) < np.mean(errs_raw) / 3


def test_partial_feedback_only_updates_on_offload():
    cal = LinUCBCalibrator(dim=1)
    A0 = cal.A.copy()
    # no update call => state untouched (partial feedback contract)
    _ = cal.calibrated(0.5, [0.1])
    _ = cal.ucb(0.5, [0.1])
    assert np.allclose(cal.A, A0)
