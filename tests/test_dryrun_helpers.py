"""Dry-run accounting helpers (pure logic — no 512-device mesh needed).

Importing repro.launch.dryrun sets XLA_FLAGS but jax is already
initialized by conftest, so the env var has no effect here.
"""

from repro.configs import get_config
from repro.configs.base import SHAPES


def _dr():
    from repro.launch import dryrun
    return dryrun


def test_collective_bytes_parsing():
    dr = _dr()
    hlo = "\n".join([
        "%ar = bf16[8,128]{1,0} all-reduce(%x), replica_groups=...",
        "%ag = f32[4,64]{1,0} all-gather(%y), dimensions={0}",
        "%t = (f32[16]{0}, f32[16]{0}) all-reduce(%a, %b), to_apply=add",
        "%aa = bf16[2,2]{1,0} all-to-all(%z)",
        "%cp = u32[10]{0} collective-permute(%w)",
        "%noise = f32[999]{0} add(%p, %q)",
        "%start = bf16[4]{0} all-reduce-start(%v)",
    ])
    out = dr.collective_bytes(hlo)
    assert out["all-reduce"] == 8 * 128 * 2 + 2 * 16 * 4 + 4 * 2
    assert out["all-gather"] == 4 * 64 * 4
    assert out["all-to-all"] == 2 * 2 * 2
    assert out["collective-permute"] == 10 * 4
    assert out["total"] == sum(out[k] for k in
                               ("all-gather", "all-reduce", "reduce-scatter",
                                "all-to-all", "collective-permute"))


def test_model_flops_scaling():
    dr = _dr()
    cfg = get_config("qwen2-1.5b")
    f_train = dr.model_flops(cfg, SHAPES["train_4k"])
    f_pref = dr.model_flops(cfg, SHAPES["prefill_32k"])
    f_dec = dr.model_flops(cfg, SHAPES["decode_32k"])
    # train = 3x forward at equal token count
    assert abs(f_train / (2.0 * cfg.param_count() *
                          SHAPES["train_4k"].global_batch *
                          SHAPES["train_4k"].seq_len) - 3.0) < 1e-6
    assert f_dec < f_pref < f_train


def test_model_flops_moe_uses_active():
    dr = _dr()
    cfg = get_config("kimi-k2-1t-a32b")
    f = dr.model_flops(cfg, SHAPES["decode_32k"])
    assert f < 2.0 * cfg.param_count() * 128 * 0.2   # far below dense count


def test_depth_variants_respect_family_granularity():
    dr = _dr()
    for arch, expect in (("qwen2-1.5b", (2, 4)),
                         ("zamba2-7b", (6, 12)),
                         ("xlstm-350m", (2, 4))):
        cfg = get_config(arch)
        (ca, a), (cb, b) = dr._depth_variants(cfg)
        if cfg.family == "ssm":
            g = cfg.mlstm_per_slstm + 1
            assert (a, b) == (g, 2 * g)
        else:
            assert (a, b) == expect
        assert ca.n_layers == a and cb.n_layers == b


def test_depth_variants_encdec_scales_both_stacks():
    dr = _dr()
    cfg = get_config("whisper-medium")
    (ca, a), (cb, b) = dr._depth_variants(cfg)
    assert ca.n_encoder_layers == a and cb.n_encoder_layers == b


def test_apply_opts():
    dr = _dr()
    cfg = get_config("mistral-large-123b")
    c2, strat = dr.apply_opts(cfg, ["blocked_attn", "expand_kv", "fsdp"])
    assert c2.attention_block_q == 512
    assert c2.kv_cache_expand_heads == 16
    assert strat == "fsdp"
    # expand_kv refuses when head counts don't align
    c3, _ = dr.apply_opts(get_config("xlstm-350m"), ["expand_kv"])
    assert c3.kv_cache_expand_heads is None


def test_extrapolate_linear():
    dr = _dr()
    assert dr._extrapolate(10.0, 20.0, 2, 4, 8) == 40.0
