"""reprolint test suite: per-rule true-positive fixtures (the bug
fires), false-positive guards (the idiomatic pattern passes),
suppression/baseline semantics, and the meta-test asserting the
repo-wide sweep is clean with the empty shipped baseline.

The analyzer is pure stdlib, so these tests need no JAX device — the
fixtures are source strings fed through ``reprolint.analyze_source``.
"""
import json
import os
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

from reprolint import ALL_RULES, RULE_NAMES, analyze_source, run  # noqa: E402
from reprolint.cli import main as cli_main  # noqa: E402


def rules_of(findings):
    return [f.rule for f in findings]


def by_rule(findings, rule):
    return [f for f in findings if f.rule == rule]


# ---------------------------------------------------------------------
# donation-discipline


def test_donation_fires_on_use_after_donate():
    # the seeded use-after-donate fixture the gating CI job must fail on
    findings = analyze_source("""
import jax
step = jax.jit(fn, donate_argnums=(0,))

def loop(x, batches):
    y = step(x)
    return x.sum() + y
""")
    hits = by_rule(findings, "donation-discipline")
    assert len(hits) == 1
    assert "'x'" in hits[0].message and "donated" in hits[0].message


def test_donation_passes_when_rebound_from_result():
    # the idiomatic pattern: donated arg reassigned in the same statement
    findings = analyze_source("""
import jax
step = jax.jit(fn, donate_argnums=(0, 1))

def train(params, opt, batches):
    for batch in batches:
        params, opt, metrics = step(params, opt, batch)
    return params, opt
""")
    assert by_rule(findings, "donation-discipline") == []


def test_donation_resolves_lru_cached_tuple_factory():
    # the engine shape: lru_cache'd factory returning a (decode, prefill)
    # tuple, reached through a self-method and tuple-unpacked
    src = """
import jax, functools

@functools.lru_cache(maxsize=8)
def _jit_steps(cfg, max_len):
    def decode_fn(params, tokens, pos, cache, key):
        return tokens, pos, cache, key
    return (jax.jit(decode_fn, donate_argnums=(2, 3)),
            jax.jit(prefill_fn, donate_argnums=(5, 6)))

class Engine:
    def _steps(self):
        return _jit_steps(self.cfg, self.max_len)

    def good(self, tok):
        decode_step, prefill_step = self._steps()
        nxt, self.pos, self.cache, self.key = decode_step(
            self.params, tok, self.pos, self.cache, self.key)
        return nxt

    def bad(self, tok):
        decode_step, prefill_step = self._steps()
        nxt = decode_step(self.params, tok, self.pos, self.cache, self.key)
        return self.cache
"""
    findings = by_rule(analyze_source(src), "donation-discipline")
    assert len(findings) == 1
    assert "'self.cache'" in findings[0].message
    # the finding is in bad(), not good()
    assert findings[0].line > src.index("def bad") / 1e9  # sanity


def test_donation_resolves_dict_cache_factory_immediate_call():
    # the _jit_copy shape: module-dict cache + immediate call
    findings = analyze_source("""
import jax
_COPY_JITS = {}

def _jit_copy(width):
    fn = _COPY_JITS.get(width)
    if fn is None:
        fn = jax.jit(copy_fn, donate_argnums=(0,))
        _COPY_JITS[width] = fn
    return fn

class Engine:
    def good(self):
        self.cache = _jit_copy(8)(self.cache, self.src)
        return self.cache

    def bad(self):
        out = _jit_copy(8)(self.cache, self.src)
        return self.cache
""")
    hits = by_rule(findings, "donation-discipline")
    assert len(hits) == 1 and "'self.cache'" in hits[0].message


# ---------------------------------------------------------------------
# thread-ownership


POOL_FIXTURE = """
class Pool:
    _THREAD_OWNERSHIP = {
        "health": "join-only",
        "stats": "shared-lock:_lock",
    }
    _WORKER_METHODS = ("work",)

    def work(self):
        %s

    def join_side(self):
        self.health[0] = "dead"
        with self._lock:
            self.stats["n"] += 1
"""


def test_ownership_fires_on_worker_mutation_of_join_only():
    # the seeded unlocked shared-mutation fixture the gate must fail on
    findings = analyze_source(POOL_FIXTURE % 'self.health[0] = "dead"')
    hits = by_rule(findings, "thread-ownership")
    assert len(hits) == 1
    assert "join-only" in hits[0].message


def test_ownership_fires_on_mutator_method_call():
    findings = analyze_source(POOL_FIXTURE % 'self.health.append("x")')
    hits = by_rule(findings, "thread-ownership")
    assert len(hits) == 1 and ".append()" in hits[0].message


def test_ownership_join_side_mutation_passes():
    findings = analyze_source(POOL_FIXTURE % "pass")
    assert by_rule(findings, "thread-ownership") == []


def test_ownership_shared_lock_requires_lock():
    findings = analyze_source(POOL_FIXTURE % 'self.stats["n"] += 1')
    hits = by_rule(findings, "thread-ownership")
    assert len(hits) == 1 and "with self._lock" in hits[0].message
    # ... and lock-held access passes (join_side in the same fixture)


def test_ownership_worker_closure_is_transitive():
    findings = analyze_source(POOL_FIXTURE % "self._helper()" + """
    def _helper(self):
        self.health[0] = "dead"
""")
    assert len(by_rule(findings, "thread-ownership")) == 1


def test_ownership_module_level_lock():
    findings = analyze_source("""
import threading
_LOCK = threading.Lock()
_JITS = {}
_MODULE_OWNERSHIP = {"_JITS": "shared-lock:_LOCK"}

def good(w):
    with _LOCK:
        return _JITS.get(w)

def bad(w):
    return _JITS.get(w)
""")
    hits = by_rule(findings, "thread-ownership")
    assert len(hits) == 1 and "'_JITS'" in hits[0].message


def test_ownership_cross_object_replica_private():
    findings = analyze_source("""
class Engine:
    _THREAD_OWNERSHIP = {"cache": "replica-private"}
    _WORKER_METHODS = ("step",)

    def step(self):
        self.cache = self.cache + 1   # own state: fine

class Pool:
    _THREAD_OWNERSHIP = {}
    _CONCURRENT_METHODS = ("step",)

    def step(self):
        for e in self.engines:
            e.cache = None            # workers may be live: flagged

    def after_join(self):
        for e in self.engines:
            e.cache = None            # not a concurrent method: fine
""")
    hits = by_rule(findings, "thread-ownership")
    assert len(hits) == 1 and "replica-private" in hits[0].message


def test_ownership_rejects_unknown_domain():
    findings = analyze_source("""
class P:
    _THREAD_OWNERSHIP = {"x": "thread-spaghetti"}
""")
    hits = by_rule(findings, "thread-ownership")
    assert len(hits) == 1 and "unknown domain" in hits[0].message


# ---------------------------------------------------------------------
# retrace-hazard


def test_retrace_fires_on_jit_in_loop():
    findings = analyze_source("""
import jax
def serve(reqs):
    for r in reqs:
        fn = jax.jit(lambda x: x + 1)
        fn(r)
""")
    hits = by_rule(findings, "retrace-hazard")
    assert len(hits) == 1 and "inside a loop" in hits[0].message


def test_retrace_fires_in_hot_function():
    findings = analyze_source("""
import jax
# reprolint: hot
def decode_tick(x):
    return jax.jit(g)(x)
""")
    assert len(by_rule(findings, "retrace-hazard")) == 1


def test_retrace_cached_factory_passes():
    findings = analyze_source("""
import jax, functools

@functools.lru_cache(maxsize=64)
def _jit_steps(cfg):
    return jax.jit(step_fn, donate_argnums=(0,))

_COPY_JITS = {}
def _jit_copy(width):
    fn = _COPY_JITS.get(width)
    if fn is None:
        fn = jax.jit(copy_fn)
        _COPY_JITS[width] = fn
    return fn

step = jax.jit(top_level_fn)
""")
    assert by_rule(findings, "retrace-hazard") == []


def test_retrace_fires_on_fstring_cache_key():
    findings = analyze_source("""
import functools

@functools.lru_cache()
def factory(tag):
    return tag

def caller(n):
    return factory(f"w{n}")
""")
    hits = by_rule(findings, "retrace-hazard")
    assert len(hits) == 1 and "f-string" in hits[0].message


def test_retrace_hashable_cache_key_passes():
    findings = analyze_source("""
import functools

@functools.lru_cache()
def factory(cfg, width, flag=False):
    return cfg

def caller(cfg):
    return factory(cfg, 128, flag=True)
""")
    assert by_rule(findings, "retrace-hazard") == []


def test_retrace_fires_on_unhashable_cache_key():
    findings = analyze_source("""
import functools

@functools.lru_cache()
def factory(shape):
    return shape

def caller(dims):
    return factory([d for d in dims])
""")
    hits = by_rule(findings, "retrace-hazard")
    assert len(hits) == 1 and "unhashable" in hits[0].message


# ---------------------------------------------------------------------
# host-sync-in-hot-path


def test_hostsync_fires_only_in_hot_functions():
    findings = analyze_source("""
import numpy as np

# reprolint: hot
def decode_commit(self):
    return np.asarray(self.nxt)

def cold_path(self):
    return np.asarray(self.nxt)
""")
    hits = by_rule(findings, "host-sync-in-hot-path")
    assert len(hits) == 1
    assert "decode_commit" in hits[0].message


def test_hostsync_host_literal_args_pass():
    findings = analyze_source("""
import numpy as np

# reprolint: hot
def launch(self):
    dst = np.asarray([c[0] for c in self.pending], np.int32)
    tab = np.asarray([1, 2, 3], np.int32)
    return dst, tab
""")
    assert by_rule(findings, "host-sync-in-hot-path") == []


def test_hostsync_item_and_float_on_jax_values():
    findings = analyze_source("""
import jax.numpy as jnp

# reprolint: hot
def tick(x):
    a = x.item()
    b = float(jnp.sum(x))
    c = float(len(x))        # host value: fine
    return a + b + c
""")
    hits = by_rule(findings, "host-sync-in-hot-path")
    assert len(hits) == 2


def test_hostsync_nested_defs_inherit_hot():
    findings = analyze_source("""
import numpy as np

# reprolint: hot
def pump_loop(self):
    def drain(h):
        return np.asarray(h.nxt)
    return drain
""")
    assert len(by_rule(findings, "host-sync-in-hot-path")) == 1


# ---------------------------------------------------------------------
# pallas-contract


PALLAS_HEADER = """
import functools
import jax.experimental.pallas as pl
from jax.experimental.pallas import tpu as pltpu
"""


def test_pallas_fires_on_scalar_prefetch_arity_mismatch():
    # kernel is missing the second scalar-prefetch ref
    findings = analyze_source(PALLAS_HEADER + """
def _kern(s_ref, x_ref, o_ref, acc):
    pass

def call(x, S):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j, s, t: (i, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j, s, t: (i, j)),
        scratch_shapes=[pltpu.VMEM((8, 8), None)],
    )
    return pl.pallas_call(_kern, grid_spec=grid_spec, out_shape=x)(S, x)
""")
    hits = by_rule(findings, "pallas-contract")
    assert len(hits) == 1
    assert "4 positional refs" in hits[0].message
    assert "supplies 5" in hits[0].message


def test_pallas_consistent_signature_passes():
    findings = analyze_source(PALLAS_HEADER + """
def _kern(s_ref, t_ref, x_ref, o_ref, acc):
    pass

def call(x, S):
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(4, 4),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j, s, t: (i, j))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j, s, t: (i, j)),
        scratch_shapes=[pltpu.VMEM((8, 8), None)],
    )
    return pl.pallas_call(_kern, grid_spec=grid_spec, out_shape=x)(S, x)
""")
    assert by_rule(findings, "pallas-contract") == []


def test_pallas_fires_on_captured_index_map():
    findings = analyze_source(PALLAS_HEADER + """
def call(x, k):
    return pl.pallas_call(
        _unresolved_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i: (i * k,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=x,
    )(x)
""")
    hits = by_rule(findings, "pallas-contract")
    assert len(hits) == 1 and "captures 'k'" in hits[0].message


def test_pallas_default_bound_capture_passes():
    # the sanctioned idiom: bind the captured value via a lambda default
    findings = analyze_source(PALLAS_HEADER + """
def call(x, g):
    return pl.pallas_call(
        _unresolved_kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i, j, g=g: (i, j // g))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=x,
    )(x)
""")
    assert by_rule(findings, "pallas-contract") == []


def test_pallas_fires_on_impure_index_map():
    findings = analyze_source(PALLAS_HEADER + """
def call(x, cfg):
    return pl.pallas_call(
        _unresolved_kernel,
        grid=(4,),
        in_specs=[pl.BlockSpec((8,), lambda i, cfg=cfg: (cfg.offset + i,))],
        out_specs=pl.BlockSpec((8,), lambda i: (i,)),
        out_shape=x,
    )(x)
""")
    hits = by_rule(findings, "pallas-contract")
    assert len(hits) == 1 and "pure index arithmetic" in hits[0].message


def test_pallas_index_map_arity_mismatch():
    findings = analyze_source(PALLAS_HEADER + """
def call(x):
    return pl.pallas_call(
        _unresolved_kernel,
        grid=(4, 2),
        in_specs=[pl.BlockSpec((8, 8), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((8, 8), lambda i, j: (i, j)),
        out_shape=x,
    )(x)
""")
    hits = by_rule(findings, "pallas-contract")
    assert len(hits) == 1 and "grid supplies 2 indices" in hits[0].message


def test_pallas_layering_blocks_direct_kernel_import():
    findings = analyze_source(
        "from repro.kernels import flash_attention\n",
        path="src/repro/models/somewhere.py")
    hits = by_rule(findings, "pallas-contract")
    assert len(hits) == 1 and "dispatch" in hits[0].message


def test_pallas_layering_allows_dispatch_and_tests():
    ok_src = ("from repro.kernels import ops\n"
              "from repro.kernels import dispatch as kd\n")
    assert analyze_source(ok_src, path="src/repro/models/layers.py") == []
    # tests/benchmarks import kernel modules directly by design
    direct = "from repro.kernels import flash_attention\n"
    assert analyze_source(direct, path="tests/test_kernels.py") == []
    # ... and so does the kernels package itself
    assert analyze_source(direct, path="src/repro/kernels/ops.py") == []


# ---------------------------------------------------------------------
# suppression + baseline semantics


def test_suppression_with_justification_silences():
    findings = analyze_source("""
import numpy as np
# reprolint: hot
def decode(self):
    return np.asarray(self.nxt)  # reprolint: disable=host-sync-in-hot-path -- the one sanctioned sync per step
""")
    assert findings == []


def test_suppression_own_line_directive():
    findings = analyze_source("""
import numpy as np
# reprolint: hot
def decode(self):
    # reprolint: disable=host-sync-in-hot-path -- sanctioned
    return np.asarray(self.nxt)
""")
    assert findings == []


def test_suppression_without_justification_rejected():
    findings = analyze_source("""
import numpy as np
# reprolint: hot
def decode(self):
    return np.asarray(self.nxt)  # reprolint: disable=host-sync-in-hot-path
""")
    # the suppression is rejected AND does not take effect
    assert sorted(rules_of(findings)) == ["host-sync-in-hot-path",
                                          "reprolint-directive"]
    directive = by_rule(findings, "reprolint-directive")[0]
    assert "justification" in directive.message


def test_suppression_unknown_rule_rejected():
    findings = analyze_source(
        "x = 1  # reprolint: disable=made-up-rule -- because\n")
    assert rules_of(findings) == ["reprolint-directive"]
    assert "unknown rule" in findings[0].message


def test_unrecognised_directive_rejected():
    findings = analyze_source("x = 1  # reprolint: enable=everything\n")
    assert rules_of(findings) == ["reprolint-directive"]


def test_baseline_filters_fingerprinted_findings(tmp_path):
    src = """
import jax
step = jax.jit(fn, donate_argnums=(0,))
def f(x):
    y = step(x)
    return x
"""
    # no baseline: fires
    unfiltered = run(["fix.py"], ALL_RULES, sources={"fix.py": src})
    assert len(unfiltered.findings) == 1
    # baseline carrying the finding's fingerprint: filtered, ok exit
    base = tmp_path / "baseline.json"
    base.write_text(json.dumps(
        [unfiltered.findings[0].to_json()]))
    filtered = run(["fix.py"], ALL_RULES, baseline=base,
                   sources={"fix.py": src})
    assert filtered.findings == [] and filtered.baseline_hits == 1
    assert filtered.ok


# ---------------------------------------------------------------------
# meta: the repo itself is clean, and the gate has teeth


def test_repo_sweep_is_clean_with_empty_baseline():
    shipped = REPO / "tools" / "reprolint" / "baseline.json"
    assert json.loads(shipped.read_text()) == [], \
        "the shipped baseline must stay empty (strict gate)"
    result = run([str(REPO / "src"), str(REPO / "tests"),
                  str(REPO / "benchmarks")], ALL_RULES, baseline=shipped)
    assert result.findings == [], "repo sweep must be clean:\n" + \
        "\n".join(f.render() for f in result.findings)
    assert result.n_files > 50


def test_engine_suppressions_are_load_bearing():
    # the sanctioned syncs in engine.py are real findings held back by
    # justified suppressions — stripping the directives must re-fire them
    import re
    src = (REPO / "src" / "repro" / "serving" / "engine.py").read_text()
    stripped = re.sub(r"#\s*reprolint:\s*disable=[^\n]*", "#", src)
    findings = analyze_source(stripped, path="src/repro/serving/engine.py")
    assert len(by_rule(findings, "host-sync-in-hot-path")) >= 3


def test_cli_exit_codes(tmp_path):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n"
                   "step = jax.jit(fn, donate_argnums=(0,))\n"
                   "def f(x):\n"
                   "    y = step(x)\n"
                   "    return x\n")
    ok = tmp_path / "ok.py"
    ok.write_text("x = 1\n")
    assert cli_main([str(ok), "--no-baseline"]) == 0
    assert cli_main([str(bad), "--no-baseline"]) == 1
    assert cli_main(["--list-rules"]) == 0


def test_cli_json_output(tmp_path, capsys):
    bad = tmp_path / "bad.py"
    bad.write_text("import jax\n"
                   "step = jax.jit(fn, donate_argnums=(0,))\n"
                   "def f(x):\n"
                   "    y = step(x)\n"
                   "    return x\n")
    code = cli_main([str(bad), "--no-baseline", "--json"])
    out = json.loads(capsys.readouterr().out)
    assert code == 1
    assert out["files"] == 1
    assert out["counts"] == {"donation-discipline": 1}
    assert out["findings"][0]["rule"] == "donation-discipline"
    assert out["findings"][0]["severity"] == "error"


def test_module_entrypoint_runs():
    # the exact invocation the gating CI job uses
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "tools")
    proc = subprocess.run(
        [sys.executable, "-m", "reprolint", "src", "tests", "benchmarks"],
        cwd=str(REPO), env=env, capture_output=True, text=True, timeout=120)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "clean" in proc.stdout


def test_rule_registry_names_stable():
    assert RULE_NAMES == ("donation-discipline", "thread-ownership",
                          "retrace-hazard", "host-sync-in-hot-path",
                          "pallas-contract")
