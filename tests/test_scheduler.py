"""Dependency-triggered scheduler (Algorithm 1 Stage 2) invariants."""
import hypothesis.strategies as st
import numpy as np
from hypothesis import given, settings

from repro.core.hybridflow import Pipeline, StaticPolicy, RandomPolicy
from repro.core.planner import SyntheticPlanner
from repro.core.scheduler import run_query, Schedule, WorldModelExecutor
from repro.core.dag import topological_order
from repro.data.tasks import gen_benchmark, WorldModel


def _setup(n=20, bench="gpqa"):
    wm = WorldModel()
    pipe = Pipeline(wm=wm)
    qs = gen_benchmark(bench, n)
    return wm, pipe, qs


def test_dependencies_respected_in_schedule():
    """Property: no subtask starts before all its parents finished."""
    wm, pipe, qs = _setup(30)
    pol = RandomPolicy(0.5)
    for q in qs:
        dag, status = pipe.plan(q)
        sched = Schedule()
        run_query(q, dag, pol, pipe.edge, pipe.cloud, schedule_out=sched)
        start = {sid: s for (s, e, sid, r) in sched.events}
        end = {sid: e for (s, e, sid, r) in sched.events}
        for nd in dag.nodes:
            for d in nd.deps:
                assert end[d] <= start[nd.sid] + 1e-9, (q.qid, nd.sid, d)


def test_edge_concurrency_respected():
    wm, pipe, qs = _setup(20)
    pol = StaticPolicy(0)   # everything on the 1-slot edge
    for q in qs:
        dag, _ = pipe.plan(q)
        sched = Schedule()
        run_query(q, dag, pol, pipe.edge, pipe.cloud, schedule_out=sched)
        evs = sorted((s, e) for (s, e, sid, r) in sched.events)
        for (s1, e1), (s2, e2) in zip(evs, evs[1:]):
            assert s2 >= e1 - 1e-9   # serialized on one slot


def test_parallel_no_slower_than_chain():
    wm, pipe, qs = _setup(40)
    pol = StaticPolicy(1)
    for q in qs:
        dag, _ = pipe.plan(q)
        par = run_query(q, dag, pol, pipe.edge, pipe.cloud)
        cha = run_query(q, dag, pol, pipe.edge, pipe.cloud, chain=True)
        assert par.latency <= cha.latency + 1e-9
        # identical routing => identical cost and accuracy (common RNs)
        assert abs(par.api_cost - cha.api_cost) < 1e-9
        assert par.final_correct == cha.final_correct


def test_makespan_at_least_critical_path():
    wm, pipe, qs = _setup(20)
    pol = StaticPolicy(1)
    for q in qs:
        dag, _ = pipe.plan(q)
        res = run_query(q, dag, pol, pipe.edge, pipe.cloud)
        # longest chain of latencies is a lower bound
        order = topological_order(dag)
        depth = {}
        for sid in order:
            nd = dag.node(sid)
            lat = res.results[sid].latency
            depth[sid] = lat + max((depth[d] for d in nd.deps), default=0.0)
        assert res.latency >= max(depth.values()) - 1e-6


def test_offload_accounting():
    wm, pipe, qs = _setup(10)
    res = pipe.random(qs, p=1.0)
    assert res.offload_rate == 1.0
    assert res.api_cost > 0
    res0 = pipe.random(qs, p=0.0)
    assert res0.offload_rate == 0.0
    assert res0.api_cost == 0.0


def test_world_model_common_random_numbers():
    """Toggling one subtask leaves other subtasks' draws unchanged."""
    wm = WorldModel()
    q = gen_benchmark("gpqa", 1)[0]
    base = {s.sid: 0 for s in q.subtasks}
    r1 = dict(base)
    r1[q.subtasks[0].sid] = 1
    out0 = wm.execute(q, base)
    out1 = wm.execute(q, r1)
    # downstream changes only via parent-correctness, not via reseeding:
    # if the toggled node is correct in both, everything matches
    if out0[0] == out1[0]:
        assert out0 == out1
