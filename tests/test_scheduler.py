"""Dependency-triggered scheduler (Algorithm 1 Stage 2) invariants."""
import pytest

from repro.core.hybridflow import Pipeline, StaticPolicy, RandomPolicy
from repro.core.scheduler import (FleetScheduler, run_query, Schedule,
                                  WorldModelExecutor)
from repro.core.dag import Node, PlanDAG, topological_order
from repro.data.tasks import gen_benchmark, Query, Subtask, WorldModel


def _setup(n=20, bench="gpqa"):
    wm = WorldModel()
    pipe = Pipeline(wm=wm)
    qs = gen_benchmark(bench, n)
    return wm, pipe, qs


def test_dependencies_respected_in_schedule():
    """Property: no subtask starts before all its parents finished."""
    wm, pipe, qs = _setup(30)
    pol = RandomPolicy(0.5)
    for q in qs:
        dag, status = pipe.plan(q)
        sched = Schedule()
        run_query(q, dag, pol, pipe.edge, pipe.cloud, schedule_out=sched)
        start = {sid: s for (s, e, sid, r) in sched.events}
        end = {sid: e for (s, e, sid, r) in sched.events}
        for nd in dag.nodes:
            for d in nd.deps:
                assert end[d] <= start[nd.sid] + 1e-9, (q.qid, nd.sid, d)


def test_edge_concurrency_respected():
    wm, pipe, qs = _setup(20)
    pol = StaticPolicy(0)   # everything on the 1-slot edge
    for q in qs:
        dag, _ = pipe.plan(q)
        sched = Schedule()
        run_query(q, dag, pol, pipe.edge, pipe.cloud, schedule_out=sched)
        evs = sorted((s, e) for (s, e, sid, r) in sched.events)
        for (s1, e1), (s2, e2) in zip(evs, evs[1:]):
            assert s2 >= e1 - 1e-9   # serialized on one slot


def test_parallel_no_slower_than_chain():
    wm, pipe, qs = _setup(40)
    pol = StaticPolicy(1)
    for q in qs:
        dag, _ = pipe.plan(q)
        par = run_query(q, dag, pol, pipe.edge, pipe.cloud)
        cha = run_query(q, dag, pol, pipe.edge, pipe.cloud, chain=True)
        assert par.latency <= cha.latency + 1e-9
        # identical routing => identical cost and accuracy (common RNs)
        assert abs(par.api_cost - cha.api_cost) < 1e-9
        assert par.final_correct == cha.final_correct


def test_makespan_at_least_critical_path():
    wm, pipe, qs = _setup(20)
    pol = StaticPolicy(1)
    for q in qs:
        dag, _ = pipe.plan(q)
        res = run_query(q, dag, pol, pipe.edge, pipe.cloud)
        # longest chain of latencies is a lower bound
        order = topological_order(dag)
        depth = {}
        for sid in order:
            nd = dag.node(sid)
            lat = res.results[sid].latency
            depth[sid] = lat + max((depth[d] for d in nd.deps), default=0.0)
        assert res.latency >= max(depth.values()) - 1e-6


def test_offload_accounting():
    wm, pipe, qs = _setup(10)
    res = pipe.random(qs, p=1.0)
    assert res.offload_rate == 1.0
    assert res.api_cost > 0
    res0 = pipe.random(qs, p=0.0)
    assert res0.offload_rate == 0.0
    assert res0.api_cost == 0.0


# ---- edge cases the seed never exercised --------------------------------

def _diamond_query(qid="diamond-0"):
    """4-subtask diamond: 0 -> {1, 2} -> 3."""
    sts = (Subtask(0, "explain the question", "EXPLAIN", (), 0.3, 60, 80),
           Subtask(1, "analyze branch a", "ANALYZE", (0,), 0.5, 80, 120),
           Subtask(2, "analyze branch b", "ANALYZE", (0,), 0.6, 80, 120),
           Subtask(3, "generate the answer", "GENERATE", (1, 2), 0.4, 90, 140))
    nodes = tuple(Node(s.sid, s.desc, s.role, s.deps, requires=s.requires,
                       produces=s.produces) for s in sts)
    return Query(qid, "gpqa", "diamond test query", sts), PlanDAG(nodes)


def test_empty_dag_raises():
    q, _ = _diamond_query()
    pipe = Pipeline()
    with pytest.raises(ValueError):
        run_query(q, PlanDAG(()), StaticPolicy(0), pipe.edge, pipe.cloud)


def test_single_node_dag():
    q, _ = _diamond_query()
    st = q.subtasks[3]
    solo = Query("solo-0", "gpqa", "one step", (Subtask(
        3, st.desc, st.role, (), st.difficulty, st.tok_in, st.tok_out),))
    dag = PlanDAG((Node(3, st.desc, "GENERATE", (), produces=("r3",)),))
    pipe = Pipeline()
    for chain in (False, True):
        res = run_query(solo, dag, StaticPolicy(1), pipe.edge, pipe.cloud,
                        chain=chain)
        assert set(res.results) == {3}
        assert res.latency == res.results[3].latency
        assert res.api_cost == res.results[3].api_cost


def test_chain_vs_parallel_diamond_equivalence():
    """On a diamond, chain and parallel agree on everything but makespan:
    same routing => same correctness draws and cost (common RNs); the
    parallel middle layer shaves exactly the shorter branch's latency."""
    q, dag = _diamond_query()
    pipe = Pipeline()
    pol = StaticPolicy(1)
    par = run_query(q, dag, pol, pipe.edge, pipe.cloud)
    cha = run_query(q, dag, pol, pipe.edge, pipe.cloud, chain=True)
    assert par.final_correct == cha.final_correct
    assert abs(par.api_cost - cha.api_cost) < 1e-12
    for sid in (0, 1, 2, 3):
        assert par.results[sid].correct == cha.results[sid].correct
    lats = {s: par.results[s].latency for s in (0, 1, 2, 3)}
    assert abs(cha.latency - sum(lats.values())) < 1e-9
    expect_par = lats[0] + max(lats[1], lats[2]) + lats[3]
    assert abs(par.latency - expect_par) < 1e-9


def test_dangling_dep_ignored_not_stalled():
    """A dep sid missing from the DAG must not stall the query forever
    (topological_order/children ignore it; so must the ready counters)."""
    q, dag = _diamond_query()
    nodes = list(dag.nodes)
    nodes[2] = Node(2, nodes[2].desc, "ANALYZE", (0, 99),
                    requires=("r0",), produces=("r2",))
    bad = PlanDAG(tuple(nodes))
    pipe = Pipeline()
    res = run_query(q, bad, StaticPolicy(0), pipe.edge, pipe.cloud)
    assert len(res.results) == 4          # every node executed


def test_cloud_saturation_spills_to_edge():
    """With spill enabled, a saturated cloud pool re-routes cloud-bound
    subtasks onto idle edge slots instead of queueing them."""
    wm = WorldModel()
    edge = WorldModelExecutor(wm, cloud=False, concurrency=4)
    cloud = WorldModelExecutor(wm, cloud=True, concurrency=1)
    pipe = Pipeline(wm=wm)
    qs = gen_benchmark("gpqa", 6)
    fleet = FleetScheduler(edge, cloud, spill_to_edge=True)
    for q in qs:
        dag, status = pipe.plan(q)
        fleet.submit(q, dag, StaticPolicy(1), plan_status=status)
    results = fleet.run()
    assert all(r is not None for r in results)
    assert fleet.stats["spills"] > 0
    spilled = sum(1 for r in results for v in r.offload.values() if v == 0)
    assert spilled == fleet.stats["spills"]
    # spilled subtasks really ran on the edge profile
    for r in results:
        for sid, v in r.offload.items():
            assert r.results[sid].routed_cloud == v

    # without spill the same workload keeps everything on the cloud
    fleet2 = FleetScheduler(edge, cloud, spill_to_edge=False)
    for q in qs:
        dag, status = pipe.plan(q)
        fleet2.submit(q, dag, StaticPolicy(1), plan_status=status)
    res2 = fleet2.run()
    assert fleet2.stats["spills"] == 0
    assert all(v == 1 for r in res2 for v in r.offload.values())


def test_world_model_common_random_numbers():
    """Toggling one subtask leaves other subtasks' draws unchanged."""
    wm = WorldModel()
    q = gen_benchmark("gpqa", 1)[0]
    base = {s.sid: 0 for s in q.subtasks}
    r1 = dict(base)
    r1[q.subtasks[0].sid] = 1
    out0 = wm.execute(q, base)
    out1 = wm.execute(q, r1)
    # downstream changes only via parent-correctness, not via reseeding:
    # if the toggled node is correct in both, everything matches
    if out0[0] == out1[0]:
        assert out0 == out1
