"""Data layer: tokenizer, LM pipeline, world-model invariants."""
import numpy as np
import pytest

from repro.data import tokenizer as tok
from repro.data.pipeline import packed_batches, document_stream
from repro.data.tasks import (gen_benchmark, make_query, WorldModel,
                              BENCHMARKS, CLOUD_PROFILE)


def test_tokenizer_roundtrip():
    s = "Hello, HybridFlow! üñäçøde"
    ids = tok.encode(s, eos=True)
    assert ids[0] == tok.BOS_ID and ids[-1] == tok.EOS_ID
    assert tok.decode(ids) == s


def test_packed_batches_shapes():
    it = packed_batches(batch=4, seq_len=32, seed=1)
    b = next(it)
    assert b["tokens"].shape == (4, 32)
    assert b["labels"].shape == (4, 32)
    # next-token alignment
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_stream_deterministic():
    a = [next(document_stream(3)) for _ in range(3)]
    b = [next(document_stream(3)) for _ in range(3)]
    assert a == b
    # fresh iterators with the same seed agree
    sa = document_stream(3)
    sb = document_stream(3)
    assert [next(sa) for _ in range(3)] == [next(sb) for _ in range(3)]


def test_query_generation_deterministic():
    q1 = make_query("gpqa", 7)
    q2 = make_query("gpqa", 7)
    assert q1 == q2
    q3 = make_query("gpqa", 8)
    assert q1.subtasks != q3.subtasks


def test_query_structure():
    for bench in BENCHMARKS:
        for q in gen_benchmark(bench, 20):
            assert 3 <= q.n <= 7
            assert q.subtasks[0].role == "EXPLAIN"
            assert q.subtasks[-1].role == "GENERATE"
            for st_ in q.subtasks:
                assert all(d < st_.sid for d in st_.deps)   # topological ids
                assert 0 < st_.difficulty < 1
                assert st_.tok_in > 0 and st_.tok_out > 0


def test_world_model_anchor_calibration():
    """GPQA stand-in reproduces the paper's Table 3 accuracy anchors."""
    wm = WorldModel()
    qs = gen_benchmark("gpqa", 300)
    edge = np.mean([wm.final_correct(q, {s.sid: 0 for s in q.subtasks})
                    for q in qs])
    cloud = np.mean([wm.final_correct(q, {s.sid: 1 for s in q.subtasks})
                     for q in qs])
    assert abs(edge - 0.2554) < 0.06, edge     # paper: 25.54
    assert abs(cloud - 0.5728) < 0.06, cloud   # paper: 57.28
    assert cloud > edge + 0.2


def test_cloud_latency_and_cost_scales():
    st_ = make_query("gpqa", 0).subtasks[1]
    wm = WorldModel()
    assert wm.cost(st_, 0) == 0.0
    assert wm.cost(st_, 1) > 0.0
    assert wm.latency(st_, 1) > CLOUD_PROFILE.rtt_s


def test_deltas_exact_vs_context_sampling():
    wm = WorldModel()
    q = make_query("gpqa", 3)
    st_ = q.subtasks[1]
    dq, dl, dk = wm.deltas(q, st_)
    assert dl > 0          # cloud per-call latency exceeds edge here
    assert dk > 0
    assert -1.0 <= dq <= 1.0


def test_unknown_benchmark_raises():
    with pytest.raises(KeyError):
        gen_benchmark("nope", 1)
