"""Ragged chunked-prefill Pallas kernel vs the jnp reference twin, plus the
pooled-cache end-to-end identity through ``serve_prefill_chunk``."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels import ops, ref
from repro.kernels.dispatch import pallas_enabled
from repro.models import layers as L


def _rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-3


def _case_inputs(G, S, W, H, KV, hd, seed, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = _rand(ks[0], (G, S, H, hd), dtype)
    k = _rand(ks[1], (G, W, KV, hd), dtype)
    v = _rand(ks[2], (G, W, KV, hd), dtype)
    # per-row ragged geometry: take in [0, S] (0 = pure padding row),
    # pos0 in [0, W - take] (engine invariant: kv_width >= pos0 + take)
    take = jax.random.randint(ks[3], (G,), 0, S + 1)
    pos0 = jax.random.randint(ks[4], (G,), 0, W + 1 - take)
    return q, k, v, pos0.astype(jnp.int32), take.astype(jnp.int32)


# ---- kernel vs reference twin ---------------------------------------------

RAGGED_CASES = [
    # (G, S, W, H, KV, hd, window)
    (2, 16, 64, 4, 2, 32, None),
    (3, 32, 128, 8, 8, 64, None),     # MHA
    (1, 8, 32, 4, 1, 32, 16),         # max GQA + sliding window
    (4, 24, 96, 2, 2, 16, None),      # non-block-multiple S/W
    (2, 64, 64, 2, 1, 128, 32),       # hd=128 MXU tile + window
    (5, 7, 40, 3, 1, 16, None),       # odd everything
]


@pytest.mark.parametrize("case", RAGGED_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ragged_prefill_sweep(case, dtype):
    G, S, W, H, KV, hd, window = case
    # deterministic per-case seed (hash() of a tuple containing None is
    # process-randomized before Python 3.12)
    seed = zlib.crc32(repr(case).encode())
    q, k, v, pos0, take = _case_inputs(G, S, W, H, KV, hd, seed=seed,
                                       dtype=dtype)
    out = ops.ragged_prefill_attention(q, k, v, pos0, take, window=window,
                                       bq=16, bk=32)
    want = ref.ragged_prefill_attention_ref(q, k, v, pos0, take,
                                            window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_ragged_prefill_padding_rows_are_zero():
    """take=0 rows (pure padding) and rows beyond take emit exact zeros."""
    G, S, W, H, KV, hd = 3, 12, 48, 4, 2, 16
    q, k, v, pos0, _ = _case_inputs(G, S, W, H, KV, hd, seed=11)
    take = jnp.asarray([0, 5, S], jnp.int32)
    pos0 = jnp.asarray([0, 17, W - S], jnp.int32)
    out = np.asarray(ops.ragged_prefill_attention(q, k, v, pos0, take,
                                                  bq=8, bk=16))
    assert (out[0] == 0).all()                       # fully-masked row
    assert (out[1, 5:] == 0).all()                   # padding tail
    assert np.abs(out[1, :5]).max() > 0
    assert np.abs(out[2]).max() > 0


def test_ragged_prefill_dense_matches_flash_reference():
    """pos0=0, take=S, W=S degenerates to plain causal attention."""
    G, S, H, KV, hd = 2, 32, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = _rand(ks[0], (G, S, H, hd), jnp.float32)
    k = _rand(ks[1], (G, S, KV, hd), jnp.float32)
    v = _rand(ks[2], (G, S, KV, hd), jnp.float32)
    zeros = jnp.zeros((G,), jnp.int32)
    out = ops.ragged_prefill_attention(q, k, v, zeros, zeros + S,
                                       bq=16, bk=16)
    want = ref.attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_ragged_prefill_continuation_matches_suffix_of_full():
    """A later chunk (pos0 > 0) must equal the same rows of one full-prompt
    causal attention — the chunked/continuation contract."""
    G, T, H, KV, hd = 2, 48, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    q = _rand(ks[0], (G, T, H, hd), jnp.float32)
    k = _rand(ks[1], (G, T, KV, hd), jnp.float32)
    v = _rand(ks[2], (G, T, KV, hd), jnp.float32)
    full = ref.attention_ref(q, k, v, causal=True)
    off, S = 20, 16
    out = ops.ragged_prefill_attention(
        q[:, off:off + S], k, v, jnp.full((G,), off, jnp.int32),
        jnp.full((G,), S, jnp.int32), bq=8, bk=16)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(full[:, off:off + S]),
                               atol=2e-3, rtol=2e-3)


def test_ragged_dispatch_branch_uses_kernel():
    """layers._dispatch_attention routes per-row q_offset to the kernel
    under pallas_enabled and to the twin otherwise; both must agree."""
    G, S, W, H, KV, hd = 2, 8, 32, 4, 2, 16
    q, k, v, pos0, take = _case_inputs(G, S, W, H, KV, hd, seed=3)
    with pallas_enabled(False):       # REPRO_USE_PALLAS=1 job: force the twin
        want = L._dispatch_attention(q, k, v, causal=True, window=None,
                                     q_offset=pos0, take=take)
    with pallas_enabled(True):
        out = L._dispatch_attention(q, k, v, causal=True, window=None,
                                    q_offset=pos0, take=take)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 3), st.integers(1, 40), st.integers(1, 2),
       st.sampled_from([16, 32]), st.booleans(), st.integers(0, 10_000))
def test_ragged_prefill_property(G, S, gqa, hd, windowed, seed):
    """Property: kernel == twin for arbitrary ragged geometry."""
    KV = 2
    H = KV * (2 if gqa == 2 else 1)
    W = S + 24
    q, k, v, pos0, take = _case_inputs(G, S, W, H, KV, hd, seed=seed)
    window = 8 if windowed else None
    out = ops.ragged_prefill_attention(q, k, v, pos0, take, window=window,
                                       bq=16, bk=16)
    want = ref.ragged_prefill_attention_ref(q, k, v, pos0, take,
                                            window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


def test_masked_block_skip_fires():
    """The fully-masked-block skip must actually FIRE on serving-shaped
    traces, not just mask correctly: NaN-poison every KV line that only
    dead blocks touch. A kernel that computes a dead block anyway turns
    the poison into NaN output via ``0 * NaN`` inside ``dot(p, v)``; a
    kernel whose ``pl.when`` skips it never loads the poison."""
    G, S, W, H, KV, hd = 3, 16, 64, 4, 2, 16
    bq, bk = 8, 16
    q, k, v, _, _ = _case_inputs(G, S, W, H, KV, hd, seed=21)
    take = jnp.asarray([16, 8, 0], jnp.int32)
    pos0 = jnp.asarray([0, 20, 0], jnp.int32)
    want = np.asarray(ref.ragged_prefill_attention_ref(q, k, v, pos0, take))

    kp, vp = np.asarray(k).copy(), np.asarray(v).copy()
    for g in range(G):
        # first block boundary past the last valid key pos0+take-1: every
        # block from here on is dead for EVERY q block of row g
        end = int(pos0[g] + take[g])
        boundary = -(-end // bk) * bk if end else 0
        kp[g, boundary:] = np.nan
        vp[g, boundary:] = np.nan
    out = np.asarray(ops.ragged_prefill_attention(
        jnp.asarray(q), jnp.asarray(kp), jnp.asarray(vp), pos0, take,
        bq=bq, bk=bk))
    assert np.isfinite(out).all(), "dead KV block was computed, not skipped"
    np.testing.assert_allclose(out, want, atol=2e-3, rtol=2e-3)

    # sliding window: leading blocks entirely below pos0 - window are
    # dead for every q block of the row too
    window = 8
    g1, s1 = 1, 16
    q1, k1, v1, _, _ = _case_inputs(g1, s1, W, H, KV, hd, seed=22)
    p1 = jnp.asarray([40], jnp.int32)
    t1 = jnp.asarray([16], jnp.int32)
    want1 = np.asarray(ref.ragged_prefill_attention_ref(
        q1, k1, v1, p1, t1, window=window))
    k1p, v1p = np.asarray(k1).copy(), np.asarray(v1).copy()
    low = ((int(p1[0]) - window) // bk) * bk      # blocks ending <= 32
    k1p[0, :low] = np.nan
    v1p[0, :low] = np.nan
    out1 = np.asarray(ops.ragged_prefill_attention(
        q1, jnp.asarray(k1p), jnp.asarray(v1p), p1, t1, window=window,
        bq=bq, bk=bk))
    assert np.isfinite(out1).all(), "below-window KV block was computed"
    np.testing.assert_allclose(out1, want1, atol=2e-3, rtol=2e-3)


# ---- pooled-cache end-to-end through serve_prefill_chunk ------------------

def test_engine_chunked_prefill_pallas_token_identical(model_zoo):
    """The full engine path (batched chunked prefill into the slot pool +
    greedy decode) must produce identical tokens with the Pallas ragged
    kernel (interpret mode) and the jnp reference."""
    from repro.serving.engine import ServingEngine

    cfg, params = model_zoo("qwen2-1.5b")
    prompts = ["short", "a much longer prompt with many more words in it",
               "mid sized prompt here", "x"]

    def run(use_pallas: bool):
        with pallas_enabled(use_pallas):
            eng = ServingEngine(cfg, params, batch_slots=3, max_len=96,
                                prefill_chunk=8)
            reqs = [eng.submit(p, max_new_tokens=4) for p in prompts]
            eng.run_until_done()
            assert all(r.done for r in reqs)
            return [tuple(r.output_ids) for r in reqs], eng

    want, eng_ref = run(False)
    got, eng_pl = run(True)
    assert got == want
    assert eng_pl.stats["prefill_backend"] == "pallas"
    assert eng_ref.stats["prefill_backend"] == "xla"
    # the kernel path really batched and chunked
    assert eng_pl.stats["prefill_batch_max"] >= 2
    assert eng_pl.stats["prefill_calls"] > 1
