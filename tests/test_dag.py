"""DAG validation / repair / metrics (paper Def. C.2, App. C)."""
from _prop import given, settings, st

from repro.core.dag import (Node, PlanDAG, validate, repair, chain_fallback,
                            topological_order, critical_path_length,
                            compression_ratio, N_MAX)


def _chain(n=4):
    nodes = []
    for i in range(n):
        role = "EXPLAIN" if i == 0 else ("GENERATE" if i == n - 1 else "ANALYZE")
        deps = (i - 1,) if i else ()
        nodes.append(Node(i, f"step {i}", role, deps,
                          requires=tuple(f"r{d}" for d in deps),
                          produces=(f"r{i}",)))
    return PlanDAG(tuple(nodes))


def test_valid_chain():
    assert validate(_chain()).ok


def test_chain_metrics():
    d = _chain(5)
    assert critical_path_length(d) == 5
    assert compression_ratio(d) == 0.0


def test_parallel_compression():
    nodes = [
        Node(0, "e", "EXPLAIN", (), produces=("r0",)),
        Node(1, "a", "ANALYZE", (0,), requires=("r0",), produces=("r1",)),
        Node(2, "a", "ANALYZE", (0,), requires=("r0",), produces=("r2",)),
        Node(3, "g", "GENERATE", (1, 2), requires=("r1", "r2"), produces=("r3",)),
    ]
    d = PlanDAG(tuple(nodes))
    assert validate(d).ok
    assert critical_path_length(d) == 3
    assert compression_ratio(d) == 0.25


def test_cycle_detected_and_repaired():
    nodes = list(_chain(4).nodes)
    # add back-edge 3 -> 1 making a cycle
    nodes[1] = Node(1, nodes[1].desc, "ANALYZE", (0, 3),
                    requires=("r0", "r3"), produces=("r1",),
                    confidence={0: 0.9, 3: 0.1})
    d = PlanDAG(tuple(nodes))
    assert not validate(d).ok
    fixed, status = repair(d)
    assert status in ("repaired", "fallback")
    assert validate(fixed).ok


def test_double_generate_repaired():
    nodes = list(_chain(4).nodes)
    nodes[1] = Node(1, "x", "GENERATE", (0,), requires=("r0",), produces=("r1",))
    fixed, status = repair(PlanDAG(tuple(nodes)))
    assert validate(fixed).ok
    gens = [n for n in fixed.nodes if n.role == "GENERATE"]
    assert len(gens) == 1


def test_orphan_attached_to_root():
    nodes = list(_chain(4).nodes)
    nodes[2] = Node(2, "orphan", "ANALYZE", (), produces=("r2",))
    fixed, status = repair(PlanDAG(tuple(nodes)))
    assert validate(fixed).ok


def test_oversize_truncated():
    nodes = list(_chain(N_MAX).nodes)
    nodes.append(Node(N_MAX, "extra", "ANALYZE", (0,), requires=("r0",),
                      produces=(f"r{N_MAX}",)))
    fixed, status = repair(PlanDAG(tuple(nodes)))
    assert validate(fixed).ok
    assert fixed.n <= N_MAX


def test_chain_fallback_always_valid():
    nodes = [Node(i, f"n{i}", "ANALYZE", (), produces=(f"r{i}",))
             for i in range(5)]
    fb = chain_fallback(PlanDAG(tuple(nodes)))
    assert validate(fb).ok
    assert compression_ratio(fb) == 0.0


# ---- property: repair always terminates in a valid DAG or chain ---------

@st.composite
def random_plans(draw):
    n = draw(st.integers(2, 9))
    nodes = []
    for i in range(n):
        role = draw(st.sampled_from(["EXPLAIN", "ANALYZE", "GENERATE"]))
        deps = tuple(draw(st.sets(st.integers(0, n - 1), max_size=3)))
        req = tuple(f"r{d}" for d in deps if draw(st.booleans()))
        extra_req = draw(st.booleans())
        if extra_req:
            req = req + ("r_phantom",)
        nodes.append(Node(i, f"node {i}", role, deps, requires=req,
                          produces=(f"r{i}",)))
    return PlanDAG(tuple(nodes))


@settings(max_examples=150, deadline=None)
@given(random_plans())
def test_repair_property(dag):
    fixed, status = repair(dag)
    assert status in ("valid", "repaired", "fallback")
    v = validate(fixed)
    assert v.ok, (status, v.errors)
    # scheduler invariant: repaired plans are always executable
    assert topological_order(fixed) is not None
