"""Serving consistency: prefill + one-step decode matches the full
forward for every architecture (KV caches, rolling windows, recurrent
states), plus the batched engine."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M
from repro.serving.engine import ServingEngine


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch).reduced()
    if cfg.n_experts:
        cfg = cfg.variant(capacity_factor=8.0)  # avoid drop nondeterminism
    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key, dtype=jnp.float32)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    n_img = cfg.n_image_patches if cfg.family == "vlm" else 0
    batch = {"tokens": toks[:, :S]}
    if cfg.family == "vlm":
        batch["patch_embeds"] = 0.1 * jnp.ones((B, n_img, cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = 0.1 * jnp.ones((B, cfg.encoder_seq, cfg.d_model))
    full = dict(batch)
    full["tokens"] = toks
    logits_full, _ = M.forward(params, cfg, full)

    cache = M.init_cache(cfg, B, S + n_img + 8, dtype=jnp.float32)
    lg_pre, cache = M.serve_prefill(params, cfg, batch, cache)
    ref_last = M.forward(params, cfg, batch)[0][:, -1:]
    assert float(jnp.max(jnp.abs(lg_pre - ref_last))) < 1e-4

    pos = jnp.full((B,), S + n_img, jnp.int32)
    lg_dec, cache = M.serve_decode(params, cfg, toks[:, S:S + 1], pos, cache)
    err = float(jnp.max(jnp.abs(lg_dec[:, 0] - logits_full[:, S])))
    assert err < 1e-3, err


def test_rolling_window_cache_equivalence():
    """Decode with a rolling window-cache == full forward with SWA mask."""
    cfg = get_config("mixtral-8x7b").reduced().variant(
        sliding_window=8, capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = M.init_params(cfg, key, dtype=jnp.float32)
    B, S = 1, 20   # prompt longer than the window
    toks = jax.random.randint(key, (B, S + 1), 0, cfg.vocab_size)
    logits_full, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfg, B, cfg.sliding_window, dtype=jnp.float32)
    _, cache = M.serve_prefill(params, cfg, {"tokens": toks[:, :S]}, cache)
    pos = jnp.full((B,), S, jnp.int32)
    lg_dec, _ = M.serve_decode(params, cfg, toks[:, S:S + 1], pos, cache)
    err = float(jnp.max(jnp.abs(lg_dec[:, 0] - logits_full[:, S])))
    assert err < 1e-3, err


def test_engine_batched_requests(model_zoo):
    cfg, params = model_zoo("qwen2-1.5b")
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=96)
    for i in range(5):
        eng.submit(f"request number {i}", max_new_tokens=6)
    done = eng.run_until_done()
    assert len(done) == 5
    assert all(r.done and len(r.output_ids) >= 1 for r in done)
    assert eng.stats["tokens_out"] >= 5
    # 5 requests through 2 fixed KV slots: the pool is recycled, not grown
    assert eng.stats["slot_reuses"] >= 3
    assert eng.stats["peak_active"] <= 2


def test_engine_greedy_deterministic(model_zoo):
    cfg, params = model_zoo("qwen2-1.5b")
    outs = []
    for _ in range(2):
        eng = ServingEngine(cfg, params, batch_slots=1, max_len=64)
        eng.submit("same prompt", max_new_tokens=5)
        outs.append(tuple(eng.run_until_done()[0].output_ids))
    assert outs[0] == outs[1]


def test_batched_chunked_prefill_token_identical(model_zoo):
    """The batched (and chunked) prefill planner writes KV lines straight
    into the slot pool; greedy outputs must be token-identical to the
    legacy batch-1 per-slot prefill path for the same prompts."""
    cfg, params = model_zoo("qwen2-1.5b")
    prompts = ["short", "a much longer prompt with many more words in it",
               "mid sized prompt here", "x", "another ragged length prompt"]

    def run(**kw):
        eng = ServingEngine(cfg, params, batch_slots=3, max_len=96, **kw)
        reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
        eng.run_until_done()
        assert all(r.done for r in reqs)
        return [tuple(r.output_ids) for r in reqs], eng

    ref, eng_legacy = run(batched_prefill=False)
    out_batched, eng_b = run()
    out_chunked, eng_c = run(prefill_chunk=4)
    assert out_batched == ref
    assert out_chunked == ref
    # the planner really batched: >= 2 queued requests in one prefill call
    assert eng_b.stats["prefill_batch_max"] >= 2
    assert eng_c.stats["prefill_batch_max"] >= 2
    # chunking splits long prompts across several calls
    assert eng_c.stats["prefill_calls"] > eng_b.stats["prefill_calls"]
    # same total real prompt tokens on every path (padding is not counted)
    assert (eng_b.stats["prefill_tokens"] == eng_c.stats["prefill_tokens"]
            == eng_legacy.stats["prefill_tokens"])


def test_engine_run_until_foreign_request_fails_fast(model_zoo):
    """run_until on a request submitted to a different engine must raise
    immediately instead of spinning max_steps."""
    cfg, params = model_zoo("qwen2-1.5b")
    a = ServingEngine(cfg, params, batch_slots=1, max_len=64)
    b = ServingEngine(cfg, params, batch_slots=1, max_len=64)
    r = a.submit("hello", max_new_tokens=4)
    with pytest.raises(ValueError, match="never submitted"):
        b.run_until(r)
    a.run_until(r)          # the owning engine still finishes it
    assert r.done


def test_engine_run_until_continuous_batching(model_zoo):
    """run_until(req) finishes the target request while co-resident
    requests keep decoding on the same steps (cross-query batching)."""
    cfg, params = model_zoo("qwen2-1.5b")
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=96)
    a = eng.submit("first query subtask", max_new_tokens=4)
    b = eng.submit("second query subtask", max_new_tokens=12)
    eng.run_until(a)
    assert a.done
    assert not b.done
    assert len(b.output_ids) >= 2     # b advanced alongside a
    assert eng.stats["peak_active"] == 2
    eng.run_until(b)
    assert b.done
