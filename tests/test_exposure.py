"""Privacy exposure proxy (App. D.1)."""

from repro.core.exposure import exposure, mean_exposure
from repro.core.hybridflow import Pipeline
from repro.data.tasks import gen_benchmark


def test_exposure_bounds_and_ordering():
    pipe = Pipeline()
    qs = gen_benchmark("gpqa", 40)
    edge = pipe.cot(qs, "edge")
    cloud = pipe.cot(qs, "cloud")
    e_edge, n_edge = mean_exposure(edge.results)
    e_cloud, n_cloud = mean_exposure(cloud.results)
    assert e_edge == 0.0 and n_edge == 0.0
    assert n_cloud == 1.0
    assert e_cloud > 0


def test_exposure_monotone_in_offload():
    pipe = Pipeline()
    qs = gen_benchmark("gpqa", 40)
    prev = -1.0
    for p in (0.0, 0.3, 0.7, 1.0):
        m = pipe.random(qs, p=p)
        _, nbar = mean_exposure(m.results)
        assert nbar >= prev - 0.05   # noisy monotonicity
        prev = nbar


def test_exposure_single_query():
    pipe = Pipeline()
    q = gen_benchmark("gpqa", 1)[0]
    res = pipe.cot([q], "cloud").results[0]
    e, nbar = exposure(res)
    assert e == sum(r.tok_in for r in res.results.values())
    assert nbar == 1.0
