"""Chunked-GLA Pallas kernel vs the sequential oracle (SSM hot spot)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.kernels import ops
from repro.models.linear_recurrence import gla_reference, chunked_gla


CASES = [
    (2, 16, 3, 8, 5, 4),
    (1, 33, 2, 16, 16, 8),     # ragged T vs chunk
    (2, 64, 2, 8, 8, 64),
    (1, 40, 1, 4, 6, 128),     # chunk > T
]


def _inputs(B, T, H, Dk, Dv, seed, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, T, H, Dk), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, T, H, Dk), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, T, H, Dv), jnp.float32).astype(dtype)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)))
    return q, k, v, la


@pytest.mark.parametrize("case", CASES)
def test_gla_kernel_matches_oracle(case):
    B, T, H, Dk, Dv, chunk = case
    q, k, v, la = _inputs(B, T, H, Dk, Dv, seed=sum(case))
    y1 = ops.chunked_gla(q, k, v, la, chunk=chunk)
    y2, _ = gla_reference(q, k, v, la)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)


def test_gla_kernel_matches_xla_path():
    """The kernel and the models' XLA chunked path agree on the same math."""
    q, k, v, la = _inputs(2, 48, 2, 8, 8, seed=7)
    y_k = ops.chunked_gla(q, k, v, la, chunk=16)
    y_x, _ = chunked_gla(q, k, v, la, chunk=16)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_x),
                               atol=1e-4, rtol=1e-4)


def test_gla_kernel_bf16():
    q, k, v, la = _inputs(1, 32, 2, 8, 8, seed=3, dtype=jnp.bfloat16)
    y1 = ops.chunked_gla(q, k, v, la, chunk=8)
    y2, _ = gla_reference(q, k, v, la)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=5e-2, rtol=5e-2)


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 2), st.integers(4, 50), st.integers(1, 3),
       st.sampled_from([4, 8, 16]), st.integers(0, 10_000))
def test_gla_kernel_property(B, T, H, chunk, seed):
    q, k, v, la = _inputs(B, T, H, 8, 8, seed=seed)
    y1 = ops.chunked_gla(q, k, v, la, chunk=chunk)
    y2, _ = gla_reference(q, k, v, la)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-4)
