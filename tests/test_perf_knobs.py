"""§Perf optimization knobs: numerical parity with the baselines."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M


def test_blocked_attention_model_parity():
    cfg0 = get_config("internlm2-1.8b").reduced()
    cfg1 = cfg0.variant(attention_block_q=8)
    p = M.init_params(cfg0, jax.random.PRNGKey(0), dtype=jnp.float32)
    batch = {"tokens": jnp.arange(64, dtype=jnp.int32).reshape(2, 32)
             % cfg0.vocab_size}
    l0, _ = M.forward(p, cfg0, batch)
    l1, _ = M.forward(p, cfg1, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                               atol=1e-4, rtol=1e-4)


def test_blocked_attention_swa_parity():
    cfg0 = get_config("mixtral-8x7b").reduced().variant(
        sliding_window=8, capacity_factor=8.0)
    cfg1 = cfg0.variant(attention_block_q=8)
    p = M.init_params(cfg0, jax.random.PRNGKey(1), dtype=jnp.float32)
    batch = {"tokens": jnp.arange(48, dtype=jnp.int32).reshape(2, 24)
             % cfg0.vocab_size}
    l0, _ = M.forward(p, cfg0, batch)
    l1, _ = M.forward(p, cfg1, batch)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                               atol=1e-4, rtol=1e-4)


def test_expand_kv_decode_parity():
    cfg0 = get_config("mistral-large-123b").reduced().variant(n_kv_heads=2)
    cfg1 = cfg0.variant(kv_cache_expand_heads=4)
    key = jax.random.PRNGKey(0)
    p = M.init_params(cfg0, key, dtype=jnp.float32)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S + 1), 0, cfg0.vocab_size)
    out = {}
    for name, cfg in (("base", cfg0), ("expand", cfg1)):
        cache = M.init_cache(cfg, B, S + 8, dtype=jnp.float32)
        _, cache = M.serve_prefill(p, cfg, {"tokens": toks[:, :S]}, cache)
        lg, _ = M.serve_decode(p, cfg, toks[:, S:S + 1],
                               jnp.full((B,), S, jnp.int32), cache)
        out[name] = lg
    np.testing.assert_allclose(np.asarray(out["expand"]),
                               np.asarray(out["base"]), atol=1e-5)


def test_carry_cache_decode_parity():
    cfg0 = get_config("qwen2-1.5b").reduced()
    cfg1 = cfg0.variant(carry_cache=True)
    key = jax.random.PRNGKey(2)
    p = M.init_params(cfg0, key, dtype=jnp.float32)
    B, S = 2, 10
    toks = jax.random.randint(key, (B, S + 1), 0, cfg0.vocab_size)
    out = {}
    for name, cfg in (("base", cfg0), ("carry", cfg1)):
        cache = M.init_cache(cfg, B, S + 8, dtype=jnp.float32)
        _, cache = M.serve_prefill(p, cfg, {"tokens": toks[:, :S]}, cache)
        lg, c2 = M.serve_decode(p, cfg, toks[:, S:S + 1],
                                jnp.full((B,), S, jnp.int32), cache)
        out[name] = (lg, c2)
    np.testing.assert_allclose(np.asarray(out["carry"][0]),
                               np.asarray(out["base"][0]), atol=1e-5)
    for a, b in zip(jax.tree.leaves(out["base"][1]),
                    jax.tree.leaves(out["carry"][1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_bf16_optimizer_moments():
    from repro.training.optimizer import (AdamWConfig, AdamWState,
                                          adamw_update)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = AdamWState(step=jnp.zeros((), jnp.int32),
                     mu={"w": jnp.zeros(2, jnp.bfloat16)},
                     nu={"w": jnp.zeros(2, jnp.bfloat16)})
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, schedule="constant")
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert opt.mu["w"].dtype == jnp.bfloat16       # dtype preserved
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_knapsack_policy_runs():
    from repro.core.hybridflow import Pipeline
    from repro.core.profiler import train_default_router
    router, _ = train_default_router(n_queries=60, epochs=30)
    pipe = Pipeline()
    from repro.data.tasks import gen_benchmark
    qs = gen_benchmark("gpqa", 30)
    m = pipe.knapsack(qs, router, budget=0.5)
    assert 0.0 < m.offload_rate < 1.0
    assert m.accuracy > 0.15
