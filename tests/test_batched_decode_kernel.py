"""Batched decode-attention Pallas kernel vs the jnp reference, plus the
decode hot path end-to-end through the live ServingEngine (token
identity with the Pallas dispatch toggled, greedy sampling under
``jax_debug_nans``, and the bounded-retrace contract of the jitted
step pair)."""
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.batched_decode_attention import (
    batched_decode_attention_bhmd)
from repro.kernels.decode_attention import decode_attention_bhmd
from repro.kernels.dispatch import pallas_enabled


def _inputs(B, M, H, KV, hd, seed, dtype=jnp.float32):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, M, KV, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, M, KV, hd), jnp.float32).astype(dtype)
    kv_len = jax.random.randint(ks[3], (B,), 1, M + 1).astype(jnp.int32)
    return q, k, v, kv_len


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-3


# ---- kernel vs reference --------------------------------------------------

BATCHED_DECODE_CASES = [
    # (B, M, H, KV, hd, window)
    (2, 64, 4, 2, 32, None),
    (3, 130, 8, 8, 64, None),       # MHA, non-block-multiple cache
    (1, 512, 2, 1, 128, None),      # KV=1, hd=128 MXU tile
    (4, 96, 12, 2, 64, None),       # GQA group of 6
    (2, 64, 4, 2, 32, 16),          # sliding window over a full cache
    (3, 100, 6, 1, 32, 48),         # window + KV=1, ragged tail block
]


@pytest.mark.parametrize("case", BATCHED_DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_batched_decode_sweep(case, dtype):
    B, M, H, KV, hd, window = case
    seed = zlib.crc32(repr(case).encode())
    q, k, v, kv_len = _inputs(B, M, H, KV, hd, seed=seed, dtype=dtype)
    out = ops.decode_attention(q, k, v, kv_len=kv_len, window=window, bk=32)
    want = ref.decode_attention_ref(q, k, v, kv_len, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=_tol(dtype), rtol=_tol(dtype))


def test_batched_decode_kv_len_zero_rows_are_zero():
    """Slots with nothing in their cache skip every KV block and emit
    exact zeros (the safe-denominator finish), while live rows in the
    same launch stay correct."""
    B, M, H, KV, hd = 4, 64, 4, 2, 32
    q, k, v, kv_len = _inputs(B, M, H, KV, hd, seed=7)
    kv_len = kv_len.at[0].set(0).at[2].set(0)
    out = np.asarray(ops.decode_attention(q, k, v, kv_len=kv_len, bk=16))
    assert (out[0] == 0).all()
    assert (out[2] == 0).all()
    live = np.asarray([1, 3])
    want = np.asarray(ref.decode_attention_ref(q, k, v, kv_len))
    np.testing.assert_allclose(out[live], want[live], atol=2e-3, rtol=2e-3)


@pytest.mark.parametrize("M", [1, 3, 4, 7])
def test_decode_small_kv_width_parity(M):
    """Caches narrower than a block: ``bk`` clamps to the cache width and
    the non-multiple tail is padded+masked — in BOTH decode kernels (the
    per-head reference kernel and the batched serving kernel)."""
    B, H, KV, hd = 2, 4, 2, 16
    q, k, v, _ = _inputs(B, M, H, KV, hd, seed=M)
    kv_len = jnp.asarray([M, max(1, M - 1)], jnp.int32)
    want = np.asarray(ref.decode_attention_ref(q, k, v, kv_len))
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    per_head = decode_attention_bhmd(q[:, 0], kt, vt, kv_len, bk=512)
    batched = batched_decode_attention_bhmd(q[:, 0], kt, vt, kv_len, bk=256)
    np.testing.assert_allclose(np.asarray(per_head), want[:, 0],
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(batched), want[:, 0],
                               atol=2e-3, rtol=2e-3)


def test_decode_dispatch_branch_uses_kernel():
    """layers._dispatch_attention routes the q_len==1 + kv_len decode
    shape to the batched kernel under pallas_enabled and to the jnp
    reference otherwise; both must agree."""
    from repro.models import layers as L

    B, M, H, KV, hd = 3, 48, 4, 2, 16
    q, k, v, kv_len = _inputs(B, M, H, KV, hd, seed=13)
    with pallas_enabled(False):
        want = L._dispatch_attention(q, k, v, causal=False, window=None,
                                     kv_len=kv_len)
    with pallas_enabled(True):
        out = L._dispatch_attention(q, k, v, causal=False, window=None,
                                    kv_len=kv_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-3, rtol=2e-3)


# ---- end-to-end through the live engine -----------------------------------

def test_engine_decode_pallas_token_identical(model_zoo):
    """The full engine loop (chunked prefill + per-tick batched decode,
    both dispatched through the Pallas kernels in interpret mode) must
    produce identical tokens to the jnp reference path."""
    from repro.serving.engine import ServingEngine

    cfg, params = model_zoo("qwen2-1.5b")
    prompts = ["short", "a much longer prompt with many more words in it",
               "mid sized prompt here", "x"]

    def run(use_pallas: bool):
        with pallas_enabled(use_pallas):
            eng = ServingEngine(cfg, params, batch_slots=3, max_len=96,
                                prefill_chunk=8)
            reqs = [eng.submit(p, max_new_tokens=6) for p in prompts]
            eng.run_until_done()
            assert all(r.done for r in reqs)
            return [tuple(r.output_ids) for r in reqs], eng

    want, _ = run(False)
    got, eng_pl = run(True)
    assert got == want
    assert eng_pl.stats["prefill_backend"] == "pallas"
    # the decode loop really ran batched multi-slot ticks
    assert eng_pl.stats["peak_active"] >= 2
    assert eng_pl.stats["tokens_out"] >= len(prompts) * 5


def test_device_sample_greedy_safe_denominator():
    """Greedy rows (temperature 0) must divide by the where-selected safe
    denominator, not by zero: no inf/NaN anywhere in the sample step even
    with padded-vocab -1e9 logits, under jax_debug_nans."""
    from repro.serving.engine import _device_sample

    logits = jnp.asarray([[1.0, 3.0, -1e9, 2.0],
                          [-1e9, -1e9, 0.5, 0.25],
                          [0.0, 0.0, 0.0, -1e9]], jnp.float32)
    temps = jnp.asarray([0.0, 0.7, 0.0], jnp.float32)
    jax.config.update("jax_debug_nans", True)
    try:
        out = jax.jit(_device_sample)(logits, jax.random.PRNGKey(0), temps)
        ids = np.asarray(out)
    finally:
        jax.config.update("jax_debug_nans", False)
    assert ids[0] == 1 and ids[2] == 0          # greedy rows == argmax
    assert 0 <= ids[1] < 4


def test_engine_greedy_decode_nan_free_under_debug_nans(model_zoo):
    """A greedy fleet through the live engine with jax_debug_nans on: the
    fused decode+sample and prefill+sample steps must be NaN/inf-free
    end to end."""
    from repro.serving.engine import ServingEngine

    cfg, params = model_zoo("qwen2-1.5b")
    jax.config.update("jax_debug_nans", True)
    try:
        eng = ServingEngine(cfg, params, batch_slots=2, max_len=64,
                            prefill_chunk=8)
        reqs = [eng.submit(p, max_new_tokens=4)
                for p in ["hello there", "tiny"]]
        eng.run_until_done()
    finally:
        jax.config.update("jax_debug_nans", False)
    assert all(r.done for r in reqs)


def test_engine_retraces_bounded_across_varied_length_fleet(model_zoo):
    """stats["jit_retraces"] must stay bounded for ANY prompt-length mix:
    every prefill signature comes off the static power-of-two bucket
    ladders (g <= slots; width <= chunk bucket; kv_width <= max_len
    ladder) and decode has one shape, so (a) a varied fleet stays under
    the ladder-size bound and (b) rerunning the same length mix on a
    FRESH engine adds ZERO new compiles (the lru-shared step pair is
    the whole point)."""
    from repro.serving.engine import ServingEngine

    cfg, params = model_zoo("qwen2-1.5b")

    def fleet(lengths, seed):
        # measure the DELTA this fleet adds to the lru-SHARED step cache:
        # other tests' engines share the (cfg, max_len, backend) key, so
        # the absolute count depends on test ordering, but what one fleet
        # mix ADDS is ladder-bounded regardless
        eng = ServingEngine(cfg, params, batch_slots=3, max_len=96,
                            prefill_chunk=8, seed=seed)
        eng._track_retraces()
        base = eng.stats["jit_retraces"]
        cbase = eng.stats["prefix_seed_compiles"]
        reqs = [eng.submit("word " * n, max_new_tokens=3) for n in lengths]
        eng.run_until_done()
        assert all(r.done for r in reqs)
        return (eng.stats["jit_retraces"] - base,
                eng.stats["prefix_seed_compiles"] - cbase)

    # ladder for this shape: g in {1,2,3}; width == 8 (chunk bucket);
    # kv_width in {8, 16, 32, 64, 96}; decode is one shape
    bound = 3 * 5 + 1
    lengths = [1, 3, 5, 9, 14, 22, 30, 38]
    n1, c1 = fleet(lengths, seed=0)
    assert n1 <= bound, n1
    # rerunning the SAME length mix on a fresh engine adds ZERO compiles
    # (the lru-shared step pair is the whole point); the "word "*n fleet
    # shares prefixes, so the prefix-seed copy ladder obeys the same
    # contract
    n2, c2 = fleet(lengths, seed=1)
    assert n2 == 0, (n1, n2)
    assert c2 == 0, (c1, c2)
