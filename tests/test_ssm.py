"""Recurrent substrate: chunked GLA vs sequential oracle; decode parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.linear_recurrence import (chunked_gla, gla_reference,
                                            gla_decode_step)


@pytest.mark.parametrize("T,chunk", [(16, 4), (33, 8), (64, 64), (40, 128)])
def test_chunked_gla_matches_sequential(T, chunk):
    B, H, Dk, Dv = 2, 3, 8, 5
    ks = jax.random.split(jax.random.PRNGKey(T * chunk), 4)
    q = jax.random.normal(ks[0], (B, T, H, Dk))
    k = jax.random.normal(ks[1], (B, T, H, Dk))
    v = jax.random.normal(ks[2], (B, T, H, Dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)))
    y1, s1 = chunked_gla(q, k, v, log_a, chunk=chunk)
    y2, s2 = gla_reference(q, k, v, log_a)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=1e-4, rtol=1e-4)


def test_chunked_gla_initial_state():
    B, T, H, Dk, Dv = 1, 12, 2, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (B, T, H, Dk))
    k = jax.random.normal(ks[1], (B, T, H, Dk))
    v = jax.random.normal(ks[2], (B, T, H, Dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)))
    # run full T, vs split at t=5 carrying state
    y_full, s_full = chunked_gla(q, k, v, log_a, chunk=4)
    y_a, s_a = chunked_gla(q[:, :5], k[:, :5], v[:, :5], log_a[:, :5], chunk=4)
    y_b, s_b = chunked_gla(q[:, 5:], k[:, 5:], v[:, 5:], log_a[:, 5:],
                           chunk=4, initial_state=s_a)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y_a, y_b], 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(s_b), np.asarray(s_full),
                               atol=1e-4, rtol=1e-4)


def test_gla_decode_step_matches_reference():
    B, H, Dk, Dv = 2, 2, 4, 3
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    T = 6
    q = jax.random.normal(ks[0], (B, T, H, Dk))
    k = jax.random.normal(ks[1], (B, T, H, Dk))
    v = jax.random.normal(ks[2], (B, T, H, Dv))
    log_a = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, H)))
    y_ref, s_ref = gla_reference(q, k, v, log_a)
    s = jnp.zeros((B, H, Dk, Dv))
    ys = []
    for t in range(T):
        s, y = gla_decode_step(s, q[:, t], k[:, t], v[:, t], log_a[:, t])
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.stack(ys, 1)),
                               np.asarray(y_ref), atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_ref),
                               atol=1e-5, rtol=1e-5)


def test_mamba2_decode_matches_forward():
    from repro.configs import get_config
    from repro.models import ssm as S
    cfg = get_config("zamba2-7b").reduced()
    p = S.init_mamba2(jax.random.PRNGKey(0), cfg)
    B, T = 2, 10
    u = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model)) * 0.3
    y_full, _ = S.mamba2_forward(p, cfg, u)
    cache = S.mamba2_init_cache(cfg, B)
    ys = []
    for t in range(T):
        y, cache = S.mamba2_decode(p, cfg, u[:, t:t + 1], cache)
        ys.append(y)
    y_step = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_step), np.asarray(y_full),
                               atol=2e-4, rtol=2e-4)


def test_moe_dispatch_matches_dense_oracle():
    from repro.configs import get_config
    from repro.models import moe as MoE
    cfg = get_config("mixtral-8x7b").reduced().variant(capacity_factor=8.0)
    p = MoE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    y1, a1 = MoE.moe_forward(p, cfg, x)
    y2, a2 = MoE.moe_forward_dense(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=1e-4, rtol=1e-4)
    assert abs(float(a1 - a2)) < 1e-6


def test_moe_capacity_drops_tokens():
    """With tiny capacity the dispatch drops tokens (deterministically)."""
    from repro.configs import get_config
    from repro.models import moe as MoE
    cfg = get_config("mixtral-8x7b").reduced().variant(capacity_factor=0.1)
    p = MoE.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    y1, _ = MoE.moe_forward(p, cfg, x)
    y2, _ = MoE.moe_forward_dense(p, cfg, x)
    assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-3  # drops visible
    assert np.isfinite(np.asarray(y1)).all()
