"""EnginePool invariants: R=1 bit-identity (direct and through the live
FleetScheduler), least-loaded replica dispatch, saturation-gated
cloud→edge spill, derived executor concurrency, runtime replicas=
threading."""
import pytest

from repro.core.hybridflow import StaticPolicy
from repro.core.planner import SyntheticPlanner
from repro.core.scheduler import FleetScheduler
from repro.data.tasks import WorldModel, gen_benchmark
from repro.serving.engine import JAXExecutor, ServingEngine
from repro.serving.pool import EnginePool
from repro.serving.runtime import ServingRuntime

PROMPTS = ["short", "a much longer prompt with many more words in it",
           "mid sized prompt here", "x", "another ragged length prompt",
           "and one more to force slot reuse"]


def test_pool_r1_bit_identical_to_single_engine(model_zoo):
    """A one-replica pool must emit exactly the single engine's tokens:
    same seed, same admit → prefill → decode sequence per step."""
    cfg, params = model_zoo("qwen2-1.5b")
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=96)
    reqs = [eng.submit(p, max_new_tokens=6) for p in PROMPTS]
    eng.run_until_done()
    ref = [tuple(r.output_ids) for r in reqs]

    pool = EnginePool.replicate(cfg, params, replicas=1, batch_slots=2,
                                max_len=96)
    preqs = [pool.submit(p, max_new_tokens=6) for p in PROMPTS]
    pool.run_until_done()
    assert [tuple(r.output_ids) for r in preqs] == ref
    assert pool.stats["requests"] == len(PROMPTS)


def _fleet_serve(cfg, params, cloud_eng, queries):
    wm = WorldModel()
    edge = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                     max_len=128),
                       wm, cloud=False, concurrency=1)
    cloud = JAXExecutor(cloud_eng, wm, cloud=True, price_out=3.2e-5)
    from repro.serving.runtime import ServingConfig
    rt = ServingRuntime(edge, cloud, StaticPolicy(1),
                        planner=SyntheticPlanner(),
                        config=ServingConfig(max_inflight=4, pump=True))
    return rt.serve(queries)


def test_pool_r1_bit_identical_through_fleet(model_zoo):
    """Acceptance: EnginePool with R=1 produces bit-identical tokens to
    the single-engine path through the live FleetScheduler pump loop."""
    cfg, params = model_zoo("qwen2-1.5b")
    qs = gen_benchmark("gpqa", 4)
    single = _fleet_serve(cfg, params,
                          ServingEngine(cfg, params, batch_slots=4,
                                        max_len=128), qs)
    pooled = _fleet_serve(cfg, params,
                          EnginePool.replicate(cfg, params, replicas=1,
                                               batch_slots=4, max_len=128),
                          qs)
    assert pooled.n == single.n == 4
    for a, b in zip(pooled.results, single.results):
        assert a.qid == b.qid
        assert a.offload == b.offload
        assert set(a.results) == set(b.results)
        for sid in a.results:
            # answer is the decoded token stream: equality == bit-identity
            assert a.results[sid].answer == b.results[sid].answer
            assert a.results[sid].tok_out == b.results[sid].tok_out


def test_pool_least_loaded_submit(model_zoo):
    """Requests land on the replica with the smallest load; ties break to
    the lowest index — deterministic round-robin while the pool drains
    nothing."""
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                max_len=64)
    owners = [pool.submit(f"p{i}", max_new_tokens=2)._engine
              for i in range(4)]
    assert owners == [pool.engines[0], pool.engines[1],
                      pool.engines[0], pool.engines[1]]
    assert pool.pool_stats["submitted"] == [2, 2]
    assert pool.capacity == 4
    assert pool.all_saturated          # 2 requests per 2-slot replica
    pool.run_until_done()
    assert not pool.all_saturated


def test_pool_all_replicas_work_under_saturation(model_zoo):
    """More requests than total slots: every replica ends up serving and
    recycling its own KV pool."""
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                max_len=96)
    reqs = [pool.submit(p, max_new_tokens=5) for p in PROMPTS + PROMPTS]
    done = pool.run_until_done()
    assert len(done) == len(reqs) and all(r.done for r in reqs)
    occ = pool.occupancy()
    assert all(o["requests"] > 0 for o in occ)
    assert sum(o["requests"] for o in occ) == len(reqs)
    # both replicas recycled slots (bounded pool invariant, per replica)
    assert all(o["slot_reuses"] > 0 for o in occ)
    assert pool.stats["requests"] == len(reqs)
    assert pool.stats["replicas"] == 2


def test_pool_threaded_matches_sequential_pass(model_zoo):
    """Thread-per-replica passes touch strictly thread-private state, so
    tokens match the sequential launch-all/commit-all pass exactly."""
    cfg, params = model_zoo("qwen2-1.5b")
    outs = []
    for threads in (True, False):
        pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                    max_len=96, threads=threads)
        reqs = [pool.submit(p, max_new_tokens=5) for p in PROMPTS]
        pool.run_until_done()
        outs.append([tuple(r.output_ids) for r in reqs])
    assert outs[0] == outs[1]


def test_pool_run_until_foreign_request_fails_fast(model_zoo):
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=1,
                                max_len=64)
    other = ServingEngine(cfg, params, batch_slots=1, max_len=64)
    r = other.submit("hello", max_new_tokens=3)
    with pytest.raises(ValueError, match="never submitted"):
        pool.run_until(r)
    own = pool.submit("hi there", max_new_tokens=3)
    assert pool.run_until(own).done


def test_executor_concurrency_derives_from_capacity(model_zoo):
    """JAXExecutor without explicit concurrency admits replicas x slots
    subtasks; saturated() tracks live slot occupancy."""
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=3, batch_slots=2,
                                max_len=64)
    ex = JAXExecutor(pool, WorldModel(), cloud=True)
    assert ex.concurrency == pool.capacity == 6
    assert not ex.saturated()
    for i in range(6):
        pool.submit(f"q{i}", max_new_tokens=2)
    assert ex.saturated()
    # single engines derive + saturate the same way
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    ex1 = JAXExecutor(eng, WorldModel(), cloud=False)
    assert ex1.concurrency == 2
    assert not ex1.saturated()
    eng.submit("a", max_new_tokens=2)
    eng.submit("b", max_new_tokens=2)
    assert ex1.saturated()


def test_spill_only_when_every_replica_full(model_zoo):
    """Cloud→edge spill consults live pool occupancy: a cloud executor
    whose busy count hit an explicit narrow concurrency cap but whose
    replicas still have free slots must NOT spill; once every replica is
    really full, spill fires."""
    cfg, params = model_zoo("qwen2-1.5b")
    qs = gen_benchmark("gpqa", 4)
    planner = SyntheticPlanner()

    def fleet(cloud_conc):
        wm = WorldModel()
        edge = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                         max_len=128),
                           wm, cloud=False, concurrency=2)
        pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                    max_len=128)
        cloud = JAXExecutor(pool, wm, cloud=True, concurrency=cloud_conc,
                            price_out=3.2e-5)
        fl = FleetScheduler(edge, cloud, spill_to_edge=True)
        for q in qs:
            dag, status = planner.plan(q)
            fl.submit(q, dag, StaticPolicy(1), plan_status=status)
        return fl, fl.run()

    # narrow busy-cap (2) << pool capacity (4): replicas never fill, so
    # nothing may spill even though the busy count saturates constantly
    fl_narrow, res_narrow = fleet(cloud_conc=2)
    assert fl_narrow.stats["spills"] == 0
    assert all(v == 1 for r in res_narrow for v in r.offload.values())

    # derived concurrency == capacity: the busy cap and real saturation
    # coincide, so the backlog spills onto the idle edge
    fl_full, res_full = fleet(cloud_conc=None)
    assert fl_full.stats["spills"] > 0
    spilled = sum(1 for r in res_full for v in r.offload.values() if v == 0)
    assert spilled == fl_full.stats["spills"]


def test_runtime_replicas_threading(model_zoo):
    """ServingRuntime(replicas=R) scales an engine-backed cloud executor
    out to an R-replica pool: derived concurrency, per-replica stats in
    the report, analytic executors rejected."""
    cfg, params = model_zoo("qwen2-1.5b")
    wm = WorldModel()
    edge = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                     max_len=128),
                       wm, cloud=False, concurrency=1)
    cloud = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                      max_len=128),
                        wm, cloud=True, price_out=3.2e-5)
    from repro.serving.runtime import ServingConfig
    rt = ServingRuntime(edge, cloud, StaticPolicy(1),
                        planner=SyntheticPlanner(),
                        config=ServingConfig(max_inflight=4, replicas=2))
    assert isinstance(rt.cloud.engine, EnginePool)
    assert rt.cloud.engine.n_replicas == 2
    assert rt.cloud.concurrency == 4
    rep = rt.serve(gen_benchmark("gpqa", 3))
    assert rep.n == 3
    assert rep.stats["cloud_replicas"] == 2
    assert sum(rep.stats["cloud_replica_requests"]) == \
        sum(len(r.results) for r in rep.results)

    # an explicit concurrency cap is an admission policy: pooling must
    # not silently widen it to replicas x slots
    capped = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                       max_len=128),
                         wm, cloud=True, concurrency=2, price_out=3.2e-5)
    rt_capped = ServingRuntime(edge, capped, StaticPolicy(1),
                               planner=SyntheticPlanner(),
                               config=ServingConfig(replicas=2))
    assert rt_capped.cloud.engine.n_replicas == 2
    assert rt_capped.cloud.concurrency == 2

    from repro.core.hybridflow import Pipeline
    pipe = Pipeline()
    with pytest.raises(ValueError, match="engine-backed"):
        ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(1),
                       planner=pipe.planner,
                       config=ServingConfig(replicas=2))


# ---- elastic autoscaling -----------------------------------------------

def test_autoscaler_grow_shrink_to_zero_synthetic_ramp(model_zoo):
    """Drive the autoscaler through a full synthetic occupancy ramp on an
    injected clock: poke → warm → grow under load → shrink as load falls
    → scale-to-zero after the idle window → poke again on the next
    submit. No wall-clock sleeps anywhere."""
    from repro.serving.pool import AutoscalePolicy, ColdStartModel

    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=3, batch_slots=2,
                                max_len=64)
    now = [0.0]
    policy = AutoscalePolicy(min_replicas=0, scale_up_at=0.5,
                             scale_down_at=0.4, idle_to_zero_s=1.0,
                             decision_interval_s=0.0,
                             cold_start=ColdStartModel(0.1, 0.1, 0.1))
    sc = pool.arm_autoscale(policy, clock=lambda: now[0])
    assert pool.lifecycle == ["cold"] * 3
    assert pool.autoscaler is sc

    # first arrival after cold start pokes replica 0 warm
    reqs = [pool.submit("p0", max_new_tokens=2)]
    assert sc.counters["pokes"] == 1
    assert pool.lifecycle[0] == "warming"
    now[0] = 0.5
    sc.tick()
    assert pool.lifecycle[0] == "warm"

    # pile on load: occupancy over the grow threshold brings more
    # replicas out of cold (capacity 2/replica, 4 reqs > 0.5 * cap)
    reqs += [pool.submit(f"p{i}", max_new_tokens=2) for i in (1, 2, 3)]
    now[0] = 0.6
    sc.tick()
    assert sc.counters["scale_ups"] >= 1
    assert "warming" in pool.lifecycle
    now[0] = 1.2
    sc.tick()                                  # promote everything due
    warm = [i for i, s in enumerate(pool.lifecycle) if s == "warm"]
    assert len(warm) >= 2

    # load falls to one request: occupancy under scale_down_at with an
    # idle warm replica → shrink (never below one warm while loaded)
    for r in reqs[1:]:
        assert pool.cancel(r)
    now[0] = 1.3
    sc.tick()
    assert sc.counters["scale_downs"] >= 1
    assert pool.lifecycle.count("warm") >= 1

    # full drain + idle window → scale to zero
    assert pool.cancel(reqs[0])
    now[0] = 1.4
    sc.tick()                                  # starts the idle clock
    now[0] = 3.0
    sc.tick()
    assert sc.counters["scale_to_zero"] == 1
    assert pool.lifecycle.count("warm") == 0

    # next arrival pokes the pool back to life
    pool.submit("again", max_new_tokens=2)
    assert sc.counters["pokes"] == 2
    assert "warming" in pool.lifecycle
    # the event log tells the whole story in order
    actions = [a for _, a, _ in sc.events]
    assert actions[0] == "poke"
    assert "grow" in actions and "shrink" in actions \
        and "to_zero" in actions
    summary = sc.summary()
    assert summary["scale_to_zero"] == 1 and summary["pokes"] == 2


def test_autoscaler_respects_min_replicas(model_zoo):
    """min_replicas=1 starts one replica warm and never cools the last
    warm replica, no matter how long the pool idles."""
    from repro.serving.pool import AutoscalePolicy, ColdStartModel
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                max_len=64)
    now = [0.0]
    sc = pool.arm_autoscale(
        AutoscalePolicy(min_replicas=1, idle_to_zero_s=0.1,
                        decision_interval_s=0.0,
                        cold_start=ColdStartModel(0.1, 0.1, 0.1)),
        clock=lambda: now[0])
    assert pool.lifecycle == ["warm", "cold"]
    for t in (1.0, 5.0, 50.0):
        now[0] = t
        sc.tick()
    assert pool.lifecycle[0] == "warm"
    assert sc.counters["scale_to_zero"] == 0


def test_autoscale_policy_validation():
    from repro.serving.pool import AutoscalePolicy
    with pytest.raises(ValueError):
        AutoscalePolicy(min_replicas=-1)
    with pytest.raises(ValueError):
        AutoscalePolicy(scale_up_at=0.0)
    with pytest.raises(ValueError):
        AutoscalePolicy(scale_up_at=0.5, scale_down_at=0.6)


def test_elastic_pool_serves_through_fleet(model_zoo):
    """An armed pool behind the fleet scheduler still completes every
    query: warming replicas never step, but the first poke plus
    promotions give the fleet capacity as it needs it."""
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel as WM
    from repro.serving.pool import AutoscalePolicy, ColdStartModel
    from repro.serving.runtime import ServingConfig
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                max_len=128)
    wm = WM()
    edge = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                     max_len=128),
                       wm, cloud=False, concurrency=1)
    cloud = JAXExecutor(pool, wm, cloud=True, price_out=3.2e-5)
    auto = AutoscalePolicy(min_replicas=0, idle_to_zero_s=30.0,
                           cold_start=ColdStartModel(0.02, 0.02, 0.02))
    rt = ServingRuntime(edge, cloud, StaticPolicy(1),
                        planner=SyntheticPlanner(),
                        config=ServingConfig(max_inflight=4, pump=True,
                                             autoscale=auto))
    rep = rt.serve(gen_benchmark("gpqa", 3))
    assert rep.n == 3
    assert all(r is not None and len(r.results) == r.dag.n
               for r in rep.results)
    assert rt.cloud.engine.autoscaler.counters["pokes"] >= 1
    assert rep.stats["cloud_autoscale"]["promotions"] >= 1


# ---- config-path pool plumbing errors ----------------------------------

def test_replicas_config_requires_engine_backed_cloud():
    """ServingConfig(replicas=R) over an analytic cloud executor fails
    fast with a clear message instead of duck-typing its way into a
    crash mid-serve."""
    from repro.core.hybridflow import Pipeline
    from repro.serving.runtime import ServingConfig
    pipe = Pipeline()
    with pytest.raises(ValueError, match="engine-backed"):
        ServingRuntime(pipe.edge, pipe.cloud, StaticPolicy(1),
                       planner=pipe.planner,
                       config=ServingConfig(replicas=2))


def test_autoscale_config_requires_pool_backed_cloud(model_zoo):
    """autoscale= without a pooled cloud (no replicas=) is a config
    error, not a silent no-op."""
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel as WM
    from repro.serving.pool import AutoscalePolicy
    from repro.serving.runtime import ServingConfig
    cfg, params = model_zoo("qwen2-1.5b")
    cloud = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                      max_len=64),
                        WM(), cloud=True)
    edge = JAXExecutor(ServingEngine(cfg, params, batch_slots=2,
                                     max_len=64),
                       WM(), cloud=False)
    with pytest.raises(ValueError, match="EnginePool"):
        ServingRuntime(edge, cloud, StaticPolicy(1),
                       planner=SyntheticPlanner(),
                       config=ServingConfig(autoscale=AutoscalePolicy()))


# ---- EngineLike protocol -----------------------------------------------

def test_engine_like_protocol_instances(model_zoo):
    """Both engine backings satisfy the explicit protocol JAXExecutor
    types against; an arbitrary object does not."""
    from repro.serving import EngineLike
    cfg, params = model_zoo("qwen2-1.5b")
    eng = ServingEngine(cfg, params, batch_slots=2, max_len=64)
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                max_len=64)
    assert isinstance(eng, EngineLike)
    assert isinstance(pool, EngineLike)
    assert not isinstance(object(), EngineLike)
    # the executor front door exposes the same saturation answer either
    # backing gives
    ex = JAXExecutor(pool, None, cloud=True)
    assert ex.saturated() == pool.saturated() is False
