import os
import sys

# src layout without install
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import pytest

jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def model_zoo():
    """Session-scoped cache of reduced-config models: ``(cfg, params)`` per
    (arch, seed, variant) key. JAX param init dominates the runtime of the
    engine/serving tests; sharing one tiny model across test modules keeps
    the full suite in minutes instead of re-initializing per test."""
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import model as M

    cache = {}

    def build(arch: str, *, seed: int = 0, **variant):
        key = (arch, seed, tuple(sorted(variant.items())))
        if key not in cache:
            cfg = get_config(arch).reduced()
            if variant:
                cfg = cfg.variant(**variant)
            params = M.init_params(cfg, jax.random.PRNGKey(seed),
                                   dtype=jnp.float32)
            cache[key] = (cfg, params)
        return cache[key]

    return build
