"""Sharding rules: every (arch) param tree gets divisibility-valid specs,
cache specs match structure, and the dry-run passes on a small host mesh
(subprocess: XLA device count must be set before jax init)."""
import json
import os
import subprocess
import sys

import jax
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import model as M

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _mesh22():
    return jax.make_mesh((1, 1), ("data", "model"))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_structure_and_divisibility(arch):
    """Specs exist for every leaf; sharded dims divide the axis size.

    Uses the FULL config's abstract params (no allocation) against a
    trivial 1x1 mesh for structure, then validates divisibility logic
    against the production axis sizes analytically.
    """
    from repro.distributed.sharding import param_spec, _path_names
    cfg = get_config(arch)
    params = jax.eval_shape(lambda: M.init_params(cfg, jax.random.PRNGKey(0)))
    mesh = _mesh22()

    class FakeMesh:
        shape = {"data": 16, "model": 16}
        axis_names = ("data", "model")

    flat = jax.tree_util.tree_flatten_with_path(params)[0]
    assert len(flat) > 5
    for path, leaf in flat:
        spec = param_spec(path, leaf, cfg, FakeMesh())
        assert len(spec) <= len(leaf.shape)
        for ax, s in enumerate(spec):
            if s is None:
                continue
            size = 16  # model axis
            assert leaf.shape[ax] % size == 0, (
                _path_names(path), leaf.shape, spec)


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mixtral-8x7b", "zamba2-7b",
                                  "xlstm-350m", "whisper-medium"])
def test_cache_specs_cover_tree(arch):
    """Every cache leaf gets a divisibility-valid spec at production sizes."""
    from repro.distributed.sharding import cache_spec
    cfg = get_config(arch)
    cache = M.init_cache_specs(cfg, 128, 4096, jax.numpy.bfloat16)
    flat = jax.tree_util.tree_flatten_with_path(cache)[0]
    assert len(flat) >= 2
    for path, leaf in flat:
        spec = cache_spec(path, leaf, dsz=16, ms=16, dp=("data",))
        assert len(spec) <= len(leaf.shape)
        for ax, s in enumerate(spec):
            if s is None:
                continue
            assert leaf.shape[ax] % 16 == 0, (path, leaf.shape, spec)


@pytest.mark.parametrize("combo", [
    ("qwen3-4b", "train_4k"),
    ("mixtral-8x7b", "decode_32k"),
    ("xlstm-350m", "long_500k"),
])
def test_dryrun_subprocess_small_mesh(combo):
    """Full dry-run path on an 8-device host mesh (2x4)."""
    arch, shape = combo
    env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
               PYTHONPATH=SRC + os.pathsep + os.environ.get("PYTHONPATH", ""))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", arch,
         "--shape", shape, "--mesh", "single", "--mesh-shape", "2x4",
         "--no-extrapolate", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    rec = json.load(open(f"/tmp/dryrun_test/{arch}__{shape}__single.json"))
    assert rec["status"] == "ok"
    assert rec["memory"]["total_bytes"] > 0
    assert rec["cost"]["flops"] > 0


def test_long500k_skip_policy():
    from repro.launch.dryrun import applicable
    from repro.configs.base import SHAPES
    runs = {a: applicable(get_config(a), SHAPES["long_500k"])
            for a in ARCH_IDS}
    assert runs["xlstm-350m"] and runs["zamba2-7b"] and runs["mixtral-8x7b"]
    assert runs["mistral-large-123b"] and runs["qwen2-1.5b"]  # SWA variants
    assert not runs["kimi-k2-1t-a32b"] and not runs["qwen3-4b"]
    assert not runs["whisper-medium"] and not runs["llava-next-mistral-7b"]
    assert not runs["internlm2-1.8b"]
