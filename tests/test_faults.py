"""Chaos suite: deterministic fault injection against the serving stack.

Covers the failure-semantics contract end to end (see
``serving/__init__``): seeded property chaos over the sim driver (fleet
always terminates, no query dropped, budget accounting stays exact),
fault-free bit-identity with the recovery machinery armed, scheduler
timeout → retry → cloud→edge degradation on both drivers, EnginePool
worker-thread exception capture + replica failover + straggler hedging,
and the diagnostic dump on the drained-with-unfinished-queries error.

``CHAOS_SEED`` (CI matrix) shifts every fault-plan seed so three CI jobs
explore three disjoint chaos universes with the same assertions.
"""
import os

import pytest
from _prop import given, settings, st

from repro.core.dag import Node, PlanDAG
from repro.core.dual import TwoBudgetThreshold
from repro.core.hybridflow import Pipeline, StaticPolicy
from repro.core.scheduler import FleetScheduler, RetryPolicy
from repro.data.tasks import Query, Subtask, WorldModel, gen_benchmark
from repro.serving.faults import (FaultInjector, FaultPlan, InjectedFault)

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))


def _sim_fleet(pipe, queries, *, faults=None, retry=None, policy_r=1,
               global_budget=None, max_inflight=8):
    """Analytic fleet with (optionally) a fault-wrapped cloud executor."""
    cloud = pipe.cloud
    inj = None
    if faults is not None:
        inj = FaultInjector(faults)
        cloud = inj.wrap_executor(cloud, side="cloud")
    fleet = FleetScheduler(pipe.edge, cloud, max_inflight=max_inflight,
                           global_budget=global_budget, retry=retry)
    for q in queries:
        dag, status = pipe.planner.plan(q)
        fleet.submit(q, dag, StaticPolicy(policy_r), plan_status=status)
    return fleet, inj


def _result_key(results):
    return [(r.qid, r.final_correct, r.latency, r.api_cost,
             sorted((s.sid, s.latency, s.api_cost, s.correct, s.answer)
                    for s in r.results.values()),
             sorted(r.offload.items()))
            for r in results]


def test_retry_backoff_capped_exponential():
    rp = RetryPolicy(max_retries=5, backoff_base=0.1, backoff_cap=0.5)
    assert rp.backoff(0) == 0.0
    assert rp.backoff(1) == pytest.approx(0.1)
    assert rp.backoff(2) == pytest.approx(0.2)
    assert rp.backoff(3) == pytest.approx(0.4)
    assert rp.backoff(4) == 0.5       # capped
    assert rp.backoff(10) == 0.5


def test_fault_plan_parse_round_trip():
    plan = FaultPlan.parse("submit_fail=0.1,stall=0.05@0.3,crash=1@8,"
                           "crash=0@20,slow=0:4,seed=3,edge=1")
    assert plan.submit_fail_rate == 0.1
    assert plan.stall_rate == 0.05 and plan.stall_s == 0.3
    assert plan.crash_replica == ((1, 8), (0, 20))
    assert plan.slow_replica == ((0, 4),)
    assert plan.seed == 3 and plan.edge_faults
    with pytest.raises(ValueError):
        FaultPlan.parse("explode=1")
    assert FaultPlan.parse("") == FaultPlan()


def test_fault_plan_is_deterministic():
    """Same plan, same key sequence -> identical fault decisions."""
    plan = FaultPlan(seed=7, submit_fail_rate=0.3, stall_rate=0.3)
    outcomes = []
    for _ in range(2):
        inj = FaultInjector(plan)
        got = []
        for sid in range(40):
            try:
                a = inj.on_submit("cloud", "q0", sid)
                got.append(("ok", inj.stall_for("cloud", "q0", sid, a)))
            except InjectedFault:
                got.append(("fail", None))
        outcomes.append(got)
    assert outcomes[0] == outcomes[1]
    assert any(o[0] == "fail" for o in outcomes[0])
    assert any(o[1] for o in outcomes[0] if o[1] is not None)


def test_fault_free_sim_bit_identical_with_recovery_armed():
    """RetryPolicy + a zero-rate injector must not perturb a single bit
    of the schedule: same makespan, same per-subtask results, same
    dispatch count as the plain fleet."""
    pipe = Pipeline()
    queries = gen_benchmark("gpqa", 8)
    plain, _ = _sim_fleet(pipe, queries)
    r_plain = plain.run()
    armed, _ = _sim_fleet(pipe, queries, faults=FaultPlan(seed=CHAOS_SEED),
                          retry=RetryPolicy(max_retries=3, timeout_s=None))
    r_armed = armed.run()
    assert plain.makespan == armed.makespan
    assert _result_key(r_plain) == _result_key(r_armed)
    assert plain.stats["dispatched"] == armed.stats["dispatched"]
    assert armed.stats["retries"] == armed.stats["degraded"] == 0
    assert armed.stats["fault_cost"] == 0.0


@settings(max_examples=int(os.environ.get("PROP_MAX_EXAMPLES", "10")),
          deadline=None)
@given(st.integers(0, 10 ** 6), st.floats(0.0, 0.3), st.floats(0.0, 0.3),
       st.integers(1, 3))
def test_chaos_fleet_always_terminates(seed, fail_rate, stall_rate,
                                       max_retries):
    """Random cloud-side fault plans (failure/stall rates up to 30%):
    the fleet always terminates, no query is silently dropped, every
    subtask has a result, and the global budget accounting stays exact —
    spend equals completed api_cost plus the charged fault cost, and the
    dl chain equals the makespan."""
    pipe = Pipeline()
    queries = gen_benchmark("gpqa", 6)
    gb = TwoBudgetThreshold(tau0=0.0, k_max=1e9, l_max=1e9)
    plan = FaultPlan(seed=seed + 977 * CHAOS_SEED,
                     submit_fail_rate=fail_rate, stall_rate=stall_rate,
                     stall_s=60.0)
    fleet, inj = _sim_fleet(
        pipe, queries, faults=plan, global_budget=gb,
        retry=RetryPolicy(max_retries=max_retries, timeout_s=30.0))
    results = fleet.run()
    assert len(results) == len(queries)
    for r in results:
        assert r is not None
        assert len(r.results) == r.dag.n          # no subtask dropped
    done_cost = sum(r.api_cost for r in results)
    assert gb.k_used == pytest.approx(
        done_cost + fleet.stats["fault_cost"], abs=1e-9)
    assert gb.l_used == pytest.approx(fleet.makespan, abs=1e-9)
    # injector bookkeeping matches scheduler-observed faults
    assert fleet.stats["exec_faults"] == inj.stats["submit_faults"]
    n_ret = sum(r.n_retries for r in results)
    if inj.stats["submit_faults"] or fleet.stats["timeouts"]:
        assert n_ret > 0
        assert n_ret == fleet.stats["retries"] + fleet.stats["degraded"]


def test_sim_timeout_degrades_all_cloud_to_edge():
    """Every cloud attempt stalls past the deadline and retries are
    exhausted immediately -> every subtask lands on the edge, marked
    degraded, and the offload map says edge."""
    pipe = Pipeline()
    queries = gen_benchmark("gpqa", 3)
    fleet, inj = _sim_fleet(
        pipe, queries,
        faults=FaultPlan(seed=CHAOS_SEED, stall_rate=1.0, stall_s=1e4),
        retry=RetryPolicy(max_retries=0, timeout_s=30.0))
    results = fleet.run()
    for r in results:
        assert all(s.degraded for s in r.results.values())
        assert all(v == 0 for v in r.offload.values())
        assert r.api_cost == 0.0                  # nothing finished on cloud
        assert r.n_degraded == r.dag.n
    assert fleet.stats["timeouts"] == sum(r.dag.n for r in results)
    assert fleet.stats["fault_cost"] > 0          # sunk cloud spend charged


def test_exec_fault_without_retry_propagates():
    """retry=None keeps the pre-fault-tolerance contract: the injected
    exception surfaces unchanged."""
    pipe = Pipeline()
    fleet, _ = _sim_fleet(pipe, gen_benchmark("gpqa", 2),
                          faults=FaultPlan(seed=1, submit_fail_rate=1.0))
    with pytest.raises(InjectedFault):
        fleet.run()


def test_edge_exhaustion_surfaces_as_error():
    """An edge-routed subtask out of retries has nowhere to degrade to:
    the failure must surface, chained to the injected fault."""
    pipe = Pipeline()
    inj = FaultInjector(FaultPlan(seed=2, submit_fail_rate=1.0,
                                  edge_faults=True))
    fleet = FleetScheduler(inj.wrap_executor(pipe.edge, side="edge"),
                           pipe.cloud, retry=RetryPolicy(max_retries=1))
    q = gen_benchmark("gpqa", 1)[0]
    dag, status = pipe.planner.plan(q)
    fleet.submit(q, dag, StaticPolicy(0), plan_status=status)
    with pytest.raises(RuntimeError, match="failed after"):
        fleet.run()


def test_stuck_query_error_includes_diagnostics():
    """Satellite: the drained-with-unfinished-queries error must dump
    per-query state (qid, node dispositions, budget) for debuggability."""
    pipe = Pipeline()
    fleet, _ = _sim_fleet(pipe, gen_benchmark("gpqa", 2))
    with pytest.raises(RuntimeError) as ei:
        fleet._collect_results()
    msg = str(ei.value)
    assert "fleet drained with unfinished queries" in msg
    assert "qid=gpqa-0" in msg and "qid=gpqa-1" in msg
    assert "blocked(indeg>0)=" in msg and "k_used=" in msg
    assert "waiting(sid,side,attempt,not_before)=" in msg


# ---- real-engine layer: pool failover + pumped-driver recovery ---------

PLAN_KW = dict(batch_slots=2, max_len=96)


def _flat_query(qid, n=2, tok_out=6):
    sts = tuple(Subtask(i, f"{qid} part {i}", "ANALYZE", (), 0.5, 40,
                        tok_out) for i in range(n))
    dag = PlanDAG(tuple(Node(s.sid, s.desc, s.role, s.deps) for s in sts))
    return Query(qid, "gpqa", f"flat query {qid}", sts), dag


def _pool(model_zoo, replicas=2, **kw):
    from repro.serving.pool import EnginePool
    cfg, params = model_zoo("qwen2-1.5b")
    return EnginePool.replicate(cfg, params, replicas=replicas, **PLAN_KW,
                                **kw)


def test_pool_thread_exception_propagates_when_failover_off(model_zoo):
    """Satellite regression: a worker-thread step exception must reach
    the caller at the join (the seed silently lost it / could deadlock),
    without losing the sibling replica's finished work."""
    pool = _pool(model_zoo, failover=False)

    def boom():
        raise ValueError("injected step explosion")

    reqs = [pool.submit(f"prompt {i}", max_new_tokens=4) for i in range(4)]
    pool.engines[1].step = boom
    with pytest.raises(RuntimeError, match="replica 1 step failed"):
        pool.run_until_done()
    assert pool.health[1] == "dead"
    assert "injected step explosion" in pool.pool_stats["replica_errors"][0]
    del reqs


def test_pool_replica_crash_fails_over_to_survivor(model_zoo):
    """A dead replica's queued + active requests restart on the
    survivor; every request still completes."""
    pool = _pool(model_zoo)
    inj = FaultInjector(FaultPlan(seed=CHAOS_SEED,
                                  crash_replica=((1, 2),)))
    inj.wrap_pool(pool)
    reqs = [pool.submit(f"prompt number {i}", max_new_tokens=4)
            for i in range(4)]
    pool.run_until_done()
    assert all(r.done for r in reqs)
    assert all(len(r.output_ids) == 4 for r in reqs)
    assert pool.health == ["healthy", "dead"]
    assert pool.pool_stats["deaths"] == 1
    assert pool.pool_stats["failovers"] >= 1
    assert inj.stats["replica_crashes"] == 1
    # run_until on a failed-over request keeps working (re-resolves owner)
    late = pool.submit("one more prompt", max_new_tokens=3)
    assert pool.run_until(late).done


def test_pool_straggler_suspect_and_hedge(model_zoo):
    """A replica that stops progressing while holding work turns suspect
    after N passes and its work is hedged to the healthy replica."""
    pool = _pool(model_zoo, suspect_after=2)
    inj = FaultInjector(FaultPlan(seed=CHAOS_SEED,
                                  slow_replica=((0, 10 ** 6),)))
    inj.wrap_pool(pool)
    reqs = [pool.submit(f"prompt number {i}", max_new_tokens=4)
            for i in range(4)]
    pool.run_until_done()
    assert all(r.done for r in reqs)
    assert pool.pool_stats["suspects"] >= 1
    assert pool.pool_stats["hedges"] >= 1
    assert pool.health[0] == "suspect"            # never progressed


def _serve(model_zoo, queries, *, faults=None, retry=None, replicas=2):
    from repro.serving.engine import JAXExecutor, ServingEngine
    from repro.serving.runtime import ServingRuntime
    cfg, params = model_zoo("qwen2-1.5b")
    wm = WorldModel()
    edge = JAXExecutor(ServingEngine(cfg, params, **PLAN_KW), wm,
                       cloud=False)
    cloud = JAXExecutor(_pool(model_zoo, replicas=replicas), wm,
                        cloud=True, price_out=3.2e-5)
    from repro.serving.runtime import ServingConfig
    rt = ServingRuntime(edge, cloud, StaticPolicy(1),
                        config=ServingConfig(max_inflight=6, pump=True,
                                             faults=faults, retry=retry))
    for q, dag in queries:
        rt.submit(q, dag)
    return rt.serve()


def test_pumped_chaos_acceptance_12_queries(model_zoo):
    """Acceptance: 10% injected cloud submit failures + one replica crash
    mid-run — the 12-query fleet completes every query with zero raised
    exceptions and reports per-subtask retries/degraded plus pool
    failover stats."""
    queries = [_flat_query(f"q{i:02d}") for i in range(12)]
    rep = _serve(model_zoo, queries,
                 faults=FaultPlan(seed=CHAOS_SEED, submit_fail_rate=0.10,
                                  crash_replica=((1, 8),)),
                 retry=RetryPolicy(max_retries=2, timeout_s=30.0))
    assert rep.n == 12
    for r in rep.results:
        assert r is not None and len(r.results) == r.dag.n
    assert rep.stats["cloud_deaths"] == 1
    assert rep.stats["cloud_replica_health"] == ["healthy", "dead"]
    assert rep.stats["injected"]["replica_crashes"] == 1
    if rep.stats["injected"]["submit_faults"]:
        assert rep.stats["retries"] + rep.stats["degraded"] > 0
        assert sum(r.n_retries for r in rep.results) > 0


def test_pumped_fault_free_token_identical(model_zoo):
    """Recovery armed + zero-rate plan vs plain pumped serve: identical
    tokens for every subtask (the fault path is provably inert)."""
    queries = [_flat_query(f"q{i}") for i in range(4)]
    rep_a = _serve(model_zoo, queries)
    rep_b = _serve(model_zoo, queries, faults=FaultPlan(seed=CHAOS_SEED),
                   retry=RetryPolicy(max_retries=2, timeout_s=None))
    key = lambda rep: sorted((r.qid, s.sid, s.answer)
                             for r in rep.results
                             for s in r.results.values())
    assert key(rep_a) == key(rep_b)
    assert rep_b.stats["retries"] == rep_b.stats["degraded"] == 0
    assert rep_b.stats["cloud_deaths"] == 0


def test_pumped_stall_times_out_and_degrades(model_zoo):
    """A held (stalled) cloud completion trips the in-flight deadline:
    the attempt is cancelled (KV slot freed), its sunk tokens charged,
    and the subtask degrades to the edge."""
    _serve(model_zoo, [_flat_query("warm", n=1)])   # compile outside timing
    queries = [_flat_query(f"q{i}", n=1) for i in range(2)]
    rep = _serve(model_zoo, queries,
                 faults=FaultPlan(seed=CHAOS_SEED, stall_rate=1.0,
                                  stall_s=60.0),
                 retry=RetryPolicy(max_retries=0, timeout_s=2.0))
    assert rep.n == 2
    assert rep.stats["timeouts"] >= 2
    assert rep.stats["degraded"] == 2
    for r in rep.results:
        assert all(s.degraded for s in r.results.values())
        assert all(v == 0 for v in r.offload.values())
