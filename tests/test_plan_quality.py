"""Intrinsic plan-quality framework (App. D / Fig. 5)."""

from repro.core.plan_quality import score_plan, mean_quality
from repro.core.planner import SyntheticPlanner, CorruptionRates
from repro.core.dag import chain_fallback
from repro.data.tasks import gen_benchmark


def test_oracle_plan_scores_perfect():
    q = gen_benchmark("gpqa", 5)[3]
    pl = SyntheticPlanner(CorruptionRates(0, 0, 0, 0, 0, 0, 0))
    dag, status = pl.plan(q)
    assert status == "valid"
    pq = score_plan(q, dag)
    assert pq.overall == 1.0


def test_chain_plan_loses_dependency_score():
    q = gen_benchmark("gpqa", 5)[3]
    pl = SyntheticPlanner(CorruptionRates(0, 0, 0, 0, 0, 0, 0))
    dag, _ = pl.plan(q)
    chain = chain_fallback(dag)
    pq_dag = score_plan(q, dag)
    pq_chain = score_plan(q, chain)
    assert pq_chain.dependency < pq_dag.dependency
    assert pq_chain.soundness == 1.0      # nodes all present


def test_quality_ordering_across_planners():
    """More corruption => lower mean quality (monotone ordering)."""
    qs = gen_benchmark("gpqa", 60)
    clean = mean_quality(qs, SyntheticPlanner(
        CorruptionRates(0, 0, 0, 0, 0, 0, 0)))
    default = mean_quality(qs, SyntheticPlanner())
    weak = mean_quality(qs, SyntheticPlanner(CorruptionRates(
        extra_cycle=0.2, drop_edge=0.3, double_generate=0.2,
        bad_requires=0.2, oversize=0.1, garble_xml=0.1, severe_garble=0.3)))
    assert clean["overall"] >= default["overall"] >= weak["overall"]
    assert clean["overall"] == 1.0


def test_scores_bounded():
    qs = gen_benchmark("aime24", 20)
    pl = SyntheticPlanner()
    for q in qs:
        dag, _ = pl.plan(q)
        pq = score_plan(q, dag)
        for v in (pq.soundness, pq.dependency, pq.clarity, pq.attributes,
                  pq.efficiency, pq.overall):
            assert 0.0 <= v <= 1.0
