"""Seeded-random property harness with a hypothesis-compatible API subset.

The tier-1 suite originally used ``hypothesis`` for its property tests, but
the serving container does not ship it. This module exposes the small slice
of the API the tests need — ``given``, ``settings`` and an ``st`` strategies
namespace — backed by a deterministic seeded ``numpy`` generator. When real
hypothesis *is* installed it is re-exported unchanged, so the tests keep the
richer shrinking/edge-case machinery wherever available.

Usage in tests (drop-in for the hypothesis imports):

    from _prop import given, settings, st

Knobs:
  * ``PROP_MAX_EXAMPLES`` env var caps examples per property (default 20) —
    keeps the CPU suite fast; raise locally for deeper soak runs.
  * ``PROP_SEED`` env var perturbs the per-test base seed (default 0).

The fallback's generation strategy: the first examples are boundary-biased
(every strategy emits its min/max-ish corner first), then uniform draws.
Failures re-raise with the generated arguments appended so a failing example
can be reproduced as a plain unit test.
"""
from __future__ import annotations

import os
import zlib

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

try:  # pragma: no cover - exercised only where hypothesis exists
    import hypothesis.strategies as st
    from hypothesis import given, settings

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


    import math
    import string

    import numpy as np

    _MAX_EXAMPLES_CAP = int(os.environ.get("PROP_MAX_EXAMPLES", "20"))
    _BASE_SEED = int(os.environ.get("PROP_SEED", "0"))

    class Strategy:
        """A value generator: ``example(rng, i)`` draws the i-th example."""

        def __init__(self, draw_fn, corners=()):
            self._draw = draw_fn
            self._corners = tuple(corners)

        def example(self, rng, i=None):
            if i is not None and i < len(self._corners):
                c = self._corners[i]
                return c(rng) if callable(c) else c
            return self._draw(rng)

    class _Namespace:
        pass

    st = _Namespace()

    def _integers(min_value, max_value):
        return Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            corners=(min_value, max_value))

    def _floats(min_value, max_value, allow_nan=False, allow_infinity=False):
        del allow_nan, allow_infinity  # bounded ranges only in this suite
        return Strategy(
            lambda rng: float(rng.uniform(min_value, max_value)),
            corners=(min_value, max_value))

    def _booleans():
        return Strategy(lambda rng: bool(rng.integers(0, 2)),
                        corners=(False, True))

    def _sampled_from(seq):
        seq = list(seq)
        return Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))],
                        corners=(seq[0],))

    def _lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.example(rng) for _ in range(n)]
        return Strategy(draw, corners=(
            lambda rng: [elements.example(rng) for _ in range(min_size)],))

    def _sets(elements, min_size=0, max_size=10):
        def draw(rng):
            target = int(rng.integers(min_size, max_size + 1))
            out = set()
            for _ in range(8 * max(target, 1)):
                if len(out) >= target:
                    break
                out.add(elements.example(rng))
            return out
        return Strategy(draw, corners=((lambda rng: set()),)
                        if min_size == 0 else ())

    # alphabet with XML-ish structure so parser fuzz tests hit real branches
    _TEXT_ALPHABET = (string.ascii_letters + string.digits +
                      ' <>="/\\\n\t.:,;!?()[]{}-_' + "éλ∑")

    def _text(min_size=0, max_size=20, alphabet=None):
        chars = list(alphabet) if alphabet else list(_TEXT_ALPHABET)
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return "".join(chars[int(rng.integers(0, len(chars)))]
                           for _ in range(n))
        return Strategy(draw, corners=("" if min_size == 0 else None,)
                        if min_size == 0 else ())

    class _DrawFn:
        def __init__(self, rng):
            self._rng = rng

        def __call__(self, strategy):
            return strategy.example(self._rng)

    def _composite(fn):
        """``@st.composite`` — fn's first arg becomes a draw function."""
        def make(*args, **kwargs):
            return Strategy(lambda rng: fn(_DrawFn(rng), *args, **kwargs))
        return make

    st.integers = _integers
    st.floats = _floats
    st.booleans = _booleans
    st.sampled_from = _sampled_from
    st.lists = _lists
    st.sets = _sets
    st.text = _text
    st.composite = _composite

    def settings(max_examples=100, deadline=None, **_kwargs):
        """Decorator recording example budget (deadline is ignored)."""
        def deco(fn):
            fn._prop_max_examples = max_examples
            return fn
        return deco

    def given(*strategies, **kw_strategies):
        def deco(fn):
            # note: no functools.wraps — copying __wrapped__ would make
            # pytest read the original signature and demand fixtures for
            # the strategy-supplied parameters
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_prop_max_examples", None)
                if n is None:
                    n = getattr(fn, "_prop_max_examples", 100)
                n = max(1, min(int(n), _MAX_EXAMPLES_CAP))
                seed = (zlib.crc32(fn.__qualname__.encode()) ^ _BASE_SEED)
                rng = np.random.default_rng(seed)
                for i in range(n):
                    ex_args = [s.example(rng, i) for s in strategies]
                    ex_kw = {k: s.example(rng, i)
                             for k, s in kw_strategies.items()}
                    try:
                        fn(*args, *ex_args, **ex_kw, **kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"property failed on example {i}/{n} "
                            f"(seed={seed}): args={ex_args!r} "
                            f"kwargs={ex_kw!r}: {e}") from e
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco

    # tiny self-check so a broken shim fails loudly at import time
    assert math.isfinite(_floats(0.0, 1.0).example(
        np.random.default_rng(0), 2))
