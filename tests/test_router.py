"""Utility router f_θ: training, calibration, monotone behaviour."""
import numpy as np

from repro.core import embeddings as E
from repro.core.profiler import (profile_queries, build_training_set,
                                 train_default_router)
from repro.core.router import RouterConfig, Router, train_router
from repro.data.tasks import gen_benchmark, WorldModel


def test_embedding_shapes_and_determinism():
    z1 = E.embed_texts(["analyze the hard quantum step", "list simple facts"])
    z2 = E.embed_texts(["analyze the hard quantum step", "list simple facts"])
    assert z1.shape == (2, E.embedding_dim())
    np.testing.assert_array_equal(z1, z2)
    assert not np.allclose(z1[0], z1[1])


def test_router_training_reduces_mse():
    wm = WorldModel()
    qs = gen_benchmark("math500", 60)
    prof = profile_queries(qs, wm, exact=True)
    x, y = build_training_set(prof)
    cfg = RouterConfig(epochs=40, lr=1e-3)
    params, hist = train_router(cfg, x, y)
    assert hist[-1] < hist[0]
    assert hist[-1] < 0.08   # well under the target variance
    r = Router(params, cfg)
    preds = r.predict([p.desc for p in prof[:50]], 0.3)
    assert preds.shape == (50,)
    assert np.all((preds >= 0) & (preds <= 1))


def test_router_separates_difficulty():
    """Predicted utility for hard-subtask text exceeds trivial text —
    the learnable signal the routing depends on."""
    router, info = train_default_router(n_queries=120, epochs=60)
    hard = ["Analyze: prove integrate multistep hard quantum step-2 (depends on 0)"] * 4
    easy = ["Explain: recall state list simple quantum step-0 (root)"] * 4
    u_hard = float(np.mean(router.predict(hard, 0.0)))
    u_easy = float(np.mean(router.predict(easy, 0.0)))
    assert u_hard > u_easy + 0.05, (u_hard, u_easy)


def test_profiling_pairs_are_seeded():
    wm = WorldModel()
    qs = gen_benchmark("math500", 5)
    p1 = profile_queries(qs, wm, exact=True)
    p2 = profile_queries(qs, wm, exact=True)
    assert [(a.dq, a.c) for a in p1] == [(b.dq, b.c) for b in p2]
