"""Cross-request KV prefix reuse: the bit-identity contract end to end.

Engine level — seeded shared-prefix fleets must produce token-identical
greedy outputs with reuse on vs off (the acceptance property), partial
(non-block-multiple) tails always prefill, a free slot whose lines a
borrower matched stays pinned until the seed copy launches, and a
borrower that re-leases its own source reuses lines in place with no
copy. Pool level — per-replica prefix indexes break least-loaded ties
(affinity never outranks load) and die with a crashed replica (failover
restarts from the prompt on a survivor). Fleet level — reuse on/off
serve the same prompt→answer map through the live FleetScheduler pump
loop, including under injected chaos (``CHAOS_SEED`` shifts the fault
universes like the rest of the chaos suite).
"""
import os

import pytest
from _prop import given, settings, st

from repro.data import tokenizer as tok
from repro.models import kvcache as KV
from repro.serving.engine import ServingEngine

CHAOS_SEED = int(os.environ.get("CHAOS_SEED", "0"))

# a shared system prompt longer than one PREFIX_BLOCK (16 tokens ~ 15
# chars at one byte-token per char + BOS)
SYSTEM = "You are a careful assistant. Always reason step by step. "

# property tests can't take pytest fixtures through the _prop fallback's
# opaque wrapper signature, so they share one module-cached tiny model
_ZOO: dict = {}


def _lazy_zoo():
    if not _ZOO:
        import jax
        import jax.numpy as jnp

        from repro.configs import get_config
        from repro.models import model as M
        cfg = get_config("qwen2-1.5b").reduced()
        _ZOO["m"] = (cfg, M.init_params(cfg, jax.random.PRNGKey(0),
                                        dtype=jnp.float32))
    return _ZOO["m"]


def _eng(cfg, params, *, reuse, slots=3, max_len=96, chunk=8, seed=0,
         block=KV.PREFIX_BLOCK):
    return ServingEngine(cfg, params, batch_slots=slots, max_len=max_len,
                         prefill_chunk=chunk, seed=seed, prefix_reuse=reuse,
                         prefix_block=block)


def _run_fleet(eng, prompts, max_new=5):
    reqs = [eng.submit(p, max_new_tokens=max_new) for p in prompts]
    eng.run_until_done()
    assert all(r.done for r in reqs)
    return [tuple(r.output_ids) for r in reqs]


# ---- kvcache primitives --------------------------------------------------

def test_prefix_block_hashes_chained_and_block_floored():
    ids = list(range(10, 10 + 40))
    hs = KV.prefix_block_hashes(ids, block=16)
    assert len(hs) == 2                        # 40 tokens -> 2 full blocks
    # chained: block 2's hash depends on block 1's content
    other = [99] + ids[1:]
    hs2 = KV.prefix_block_hashes(other, block=16)
    assert hs2[0] != hs[0] and hs2[1] != hs[1]
    # prefix property: same leading blocks -> same leading hashes
    assert KV.prefix_block_hashes(ids[:16], block=16) == hs[:1]
    assert KV.prefix_block_hashes(ids[:15], block=16) == []


def test_copy_prefix_matches_numpy_reference():
    import numpy as np
    import jax.numpy as jnp
    L, B, M, KVh, hd = 2, 4, 32, 2, 8
    rng = np.random.default_rng(0)
    k = rng.normal(size=(L, B, M, KVh, hd)).astype(np.float32)
    v = rng.normal(size=(L, B, M, KVh, hd)).astype(np.float32)
    src = np.asarray([0, 2], np.int32)
    dst = np.asarray([1, 3], np.int32)
    ln = np.asarray([16, 7], np.int32)
    want_k, want_v = k.copy(), v.copy()
    for g in range(2):
        want_k[:, dst[g], :ln[g]] = k[:, src[g], :ln[g]]
        want_v[:, dst[g], :ln[g]] = v[:, src[g], :ln[g]]
    got_k, got_v = KV.copy_prefix(jnp.asarray(k), jnp.asarray(v),
                                  jnp.asarray(src), jnp.asarray(dst),
                                  jnp.asarray(ln), width=16)
    np.testing.assert_array_equal(np.asarray(got_k), want_k)
    np.testing.assert_array_equal(np.asarray(got_v), want_v)


# ---- engine-level identity ----------------------------------------------

def test_shared_prefix_fleet_token_identical_and_saves(model_zoo):
    """Acceptance: a shared-system-prompt fleet is bit-identical with
    reuse on vs off, and reuse-on measurably skips prefill tokens."""
    cfg, params = model_zoo("qwen2-1.5b")
    prompts = [SYSTEM + t for t in
               ("solve part one", "now part two", "and the third part",
                "a fourth subtask", "finally the fifth", "one more")]
    off = _eng(cfg, params, reuse=False)
    want = _run_fleet(off, prompts)
    on = _eng(cfg, params, reuse=True)
    got = _run_fleet(on, prompts)
    assert got == want
    assert on.stats["prefix_hits"] > 0
    assert on.stats["prefill_tokens_saved"] > 0
    # the saving is exact: off prefills everything, on skips exactly what
    # it borrowed
    assert off.stats["prefill_tokens"] == \
        on.stats["prefill_tokens"] + on.stats["prefill_tokens_saved"]
    assert off.stats["prefix_hits"] == off.stats["prefill_tokens_saved"] == 0


def test_partial_block_tail_always_prefills(model_zoo):
    """A prompt equal to a cached prompt plus a sub-block tail (and one
    EXACTLY equal) still prefills >= 1 token and stays bit-identical."""
    cfg, params = model_zoo("qwen2-1.5b")
    base = SYSTEM + "alpha beta"
    prompts = [base, base + " x", base]        # exact duplicate included
    off = _eng(cfg, params, reuse=False, slots=1)
    want = _run_fleet(off, prompts)
    on = _eng(cfg, params, reuse=True, slots=1)
    got = _run_fleet(on, prompts)
    assert got == want
    assert on.stats["prefix_hits"] >= 1
    # the proper-prefix cap: even the exact duplicate prefilled its tail
    ids = tok.encode(base)
    cap = ((len(ids) - 1) // on.prefix_block) * on.prefix_block
    assert on.stats["prefix_hits"] == 2
    # the cap keeps every borrow a PROPER prefix: even the exact
    # duplicate prefilled at least one tail token
    assert 0 < on.stats["prefill_tokens_saved"] <= 2 * cap
    assert off.stats["prefill_tokens"] == \
        on.stats["prefill_tokens"] + on.stats["prefill_tokens_saved"]


def test_in_place_reuse_skips_copy(model_zoo):
    """slots=1: the borrower always re-leases its own source slot, so
    reuse fires with ZERO cross-slot copies."""
    cfg, params = model_zoo("qwen2-1.5b")
    eng = _eng(cfg, params, reuse=True, slots=1)
    _run_fleet(eng, [SYSTEM + "first question", SYSTEM + "second question"])
    assert eng.stats["prefix_hits"] == 1
    assert eng.stats["prefix_copies"] == 0
    assert eng.stats["prefill_tokens_saved"] > 0


def test_eviction_pinned_while_borrowed(model_zoo):
    """A free source slot matched by a borrower is pinned: a co-admitted
    request must skip it (landing on the next free slot) and the pins
    clear once the batched seed copy launches."""
    cfg, params = model_zoo("qwen2-1.5b")
    eng = _eng(cfg, params, reuse=True, slots=3)
    # wave 1: fill slots 0 and 1; slot 1 caches the shared prefix
    _run_fleet(eng, ["junk padding text unrelated", SYSTEM + "seed prompt"])
    assert eng.stats["prefix_copies"] == 0
    # wave 2: two requests admitted in one pass. First-fit puts the
    # borrower on slot 0; its best source is FREE slot 1, which must be
    # pinned so the second request lands on slot 2, not slot 1.
    b = eng.submit(SYSTEM + "borrower tail", max_new_tokens=4)
    c = eng.submit("other unrelated words", max_new_tokens=4)
    eng._admit()
    assert eng.active[0] is b
    assert eng.active[1] is None               # pinned, skipped
    assert eng.active[2] is c
    assert eng._pinned == {1}
    assert eng._pending_copy == [(0, 1, eng.stats["prefill_tokens_saved"])]
    eng.run_until_done()
    assert b.done and c.done
    assert not eng._pinned and not eng._pending_copy
    assert eng.stats["prefix_copies"] == 1


def test_cancel_mid_prefill_releases_pin(model_zoo):
    """Cancelling a borrower before its seed copy launches drops the
    pending copy and frees the pinned source for the next admit."""
    cfg, params = model_zoo("qwen2-1.5b")
    eng = _eng(cfg, params, reuse=True, slots=2)
    _run_fleet(eng, ["junk padding text unrelated", SYSTEM + "seed prompt"])
    b = eng.submit(SYSTEM + "borrower tail", max_new_tokens=4)
    eng._admit()
    assert eng._pinned == {1}
    assert eng.cancel(b)
    assert not eng._pinned and not eng._pending_copy
    d = eng.submit("fresh request takes any slot", max_new_tokens=3)
    eng.run_until_done()
    assert d.done


@settings(max_examples=int(os.environ.get("PROP_MAX_EXAMPLES", "6")),
          deadline=None)
@given(st.integers(0, 10 ** 6), st.integers(2, 4), st.integers(1, 3))
def test_prop_seeded_shared_prefix_fleets_identical(seed, slots, n_groups):
    """Property: random fleets of shared-prefix groups (random group
    sizes, tails, and interleaving) are token-identical with reuse on
    vs off and never decode a different token count."""
    import random
    cfg, params = _lazy_zoo()
    rng = random.Random(seed)
    prompts = []
    for g in range(n_groups):
        head = f"shared context {g} " * rng.randint(2, 4)
        for i in range(rng.randint(1, 4)):
            prompts.append(head + f"tail {i} " * rng.randint(1, 6))
    rng.shuffle(prompts)
    off = _eng(cfg, params, reuse=False, slots=slots)
    want = _run_fleet(off, prompts, max_new=4)
    on = _eng(cfg, params, reuse=True, slots=slots)
    got = _run_fleet(on, prompts, max_new=4)
    assert got == want
    assert on.stats["tokens_out"] == off.stats["tokens_out"]
    assert off.stats["prefill_tokens"] == \
        on.stats["prefill_tokens"] + on.stats["prefill_tokens_saved"]


# ---- pool level ----------------------------------------------------------

def test_pool_prefix_affinity_breaks_load_ties(model_zoo):
    """At equal load, submit with a matching hint lands on the replica
    whose index holds the prefix — overriding the lowest-index tie-break
    but never outranking load."""
    from repro.serving.pool import EnginePool
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                max_len=96, prefill_chunk=8)
    a = pool.submit("junk padding text unrelated", max_new_tokens=3)
    b = pool.submit(SYSTEM + "seed prompt", max_new_tokens=3)
    assert b._engine is pool.engines[1]        # least-loaded tie-break
    pool.run_until_done()
    # equal (zero) load: affinity must route the sharer to replica 1
    c = pool.submit(SYSTEM + "follow-up question", max_new_tokens=3)
    assert c._engine is pool.engines[1]
    pool.run_until_done()
    assert c.done
    assert pool.engines[1].stats["prefix_hits"] >= 1
    # ...but load outranks affinity: saturate replica 1 and the next
    # sharer must go to the idle replica 0
    busy = [pool.engines[1].submit(f"fill {i}", max_new_tokens=3)
            for i in range(2)]
    d = pool.submit(SYSTEM + "another sharer", max_new_tokens=3)
    assert d._engine is pool.engines[0]
    pool.run_until_done()
    assert d.done and all(r.done for r in busy)


def test_pool_failover_restarts_on_survivor_with_warm_index(model_zoo):
    """A dead replica's prefix index dies with its KV pool: failed-over
    requests restart from the prompt on the survivor and can re-match
    whatever the SURVIVOR's index holds."""
    from repro.serving.faults import FaultInjector, FaultPlan
    from repro.serving.pool import EnginePool
    cfg, params = model_zoo("qwen2-1.5b")
    pool = EnginePool.replicate(cfg, params, replicas=2, batch_slots=2,
                                max_len=96, prefill_chunk=8)
    # warm BOTH indexes with the shared prefix, then kill replica 1 on
    # its 2nd step while it serves a sharer
    pool.engines[0].submit(SYSTEM + "warm zero", max_new_tokens=3)
    pool.engines[1].submit(SYSTEM + "warm one", max_new_tokens=3)
    pool.run_until_done()
    inj = FaultInjector(FaultPlan(seed=CHAOS_SEED, crash_replica=((1, 2),)))
    inj.wrap_pool(pool)
    reqs = [pool.submit(SYSTEM + f"sharer number {i}", max_new_tokens=4)
            for i in range(4)]
    pool.run_until_done()
    assert all(r.done and len(r.output_ids) == 4 for r in reqs)
    assert pool.health == ["healthy", "dead"]
    assert pool.pool_stats["failovers"] >= 1
    # the survivor's index served reuse hits for the failed-over restarts
    assert pool.engines[0].stats["prefix_hits"] >= 1
    assert pool.stats["prefix_hits"] >= 1      # aggregated engine-shaped


# ---- fleet level ---------------------------------------------------------

def _fleet_answers(model_zoo, *, reuse, faults=None, retry=None, n=4):
    from repro.core.hybridflow import StaticPolicy
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel, gen_benchmark
    from repro.serving.engine import JAXExecutor
    from repro.serving.runtime import ServingConfig, ServingRuntime
    cfg, params = model_zoo("qwen2-1.5b")
    wm = WorldModel()
    edge = JAXExecutor(_eng(cfg, params, reuse=reuse, slots=2, max_len=128),
                       wm, cloud=False)
    cloud = JAXExecutor(_eng(cfg, params, reuse=reuse, slots=2, max_len=128,
                             seed=1),
                        wm, cloud=True, price_out=3.2e-5)
    rt = ServingRuntime(edge, cloud, StaticPolicy(1),
                        planner=SyntheticPlanner(),
                        config=ServingConfig(max_inflight=6, pump=True,
                                             faults=faults, retry=retry))
    rep = rt.serve(gen_benchmark("gpqa", n))
    stats = rep.stats
    # greedy answers depend only on (prompt, model), NOT on dispatch
    # order or slot assignment, so a prompt->answer map is the right
    # identity key across scheduling differences
    answers = sorted((r.qid, s.sid, s.answer) for r in rep.results
                     for s in r.results.values())
    return answers, stats


def test_fleet_reuse_on_off_same_answers(model_zoo):
    """The live FleetScheduler pump loop (DAG hints armed) serves the
    same per-subtask answers with reuse on and off, and reuse-on
    reports hits from the executors' shared query context."""
    on, stats_on = _fleet_answers(model_zoo, reuse=True)
    off, stats_off = _fleet_answers(model_zoo, reuse=False)
    assert on == off
    hits = stats_on.get("edge_prefix_hits", 0) + \
        stats_on.get("cloud_prefix_hits", 0)
    assert hits > 0
    assert stats_off.get("edge_prefix_hits", 0) == 0
    assert stats_off.get("cloud_prefix_hits", 0) == 0


def test_fleet_reuse_under_chaos_completes(model_zoo):
    """Prefix hints survive retry and degradation re-dispatch: a chaos
    fleet (submit failures, recovery armed) completes every subtask with
    reuse on, and with a deterministic submit_fail-only plan the answers
    match the reuse-off run under the SAME plan."""
    from repro.core.scheduler import RetryPolicy
    from repro.serving.faults import FaultPlan
    plan = dict(seed=CHAOS_SEED + 11, submit_fail_rate=0.15)
    retry = RetryPolicy(max_retries=3, timeout_s=None)
    on, stats_on = _fleet_answers(model_zoo, reuse=True,
                                  faults=FaultPlan(**plan), retry=retry)
    off, _ = _fleet_answers(model_zoo, reuse=False,
                            faults=FaultPlan(**plan), retry=retry)
    assert on == off
    assert len(on) > 0
