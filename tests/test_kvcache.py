"""Rolling-buffer KV cache slot math (the subtle part of SWA serving)."""
import jax.numpy as jnp
import numpy as np

from repro.models import kvcache as KV


def _kv(b, s, kv, hd, start=0):
    return (jnp.arange(start, start + b * s * kv * hd, dtype=jnp.float32)
            .reshape(b, s, kv, hd))


def test_prefill_short_prompt_no_roll():
    M = 8
    ck = jnp.zeros((1, M, 1, 2))
    k = _kv(1, 5, 1, 2)
    ck2, _ = KV.write_prefill(ck, ck, k, k, window=M)
    np.testing.assert_array_equal(np.asarray(ck2[:, :5]), np.asarray(k))


def test_prefill_long_prompt_rolls_to_canonical_slots():
    """Position p must land in slot p % M so decode eviction is correct."""
    M, S = 4, 6
    ck = jnp.zeros((1, M, 1, 1))
    k = jnp.arange(S, dtype=jnp.float32).reshape(1, S, 1, 1)  # value = pos
    ck2, _ = KV.write_prefill(ck, ck, k, k, window=M)
    got = np.asarray(ck2)[0, :, 0, 0]
    # kept positions 2..5; slot p % 4: pos2->2, pos3->3, pos4->0, pos5->1
    np.testing.assert_array_equal(got, [4, 5, 2, 3])


def test_decode_write_evicts_oldest():
    M, S = 4, 6
    ck = jnp.zeros((1, M, 1, 1))
    k = jnp.arange(S, dtype=jnp.float32).reshape(1, S, 1, 1)
    ck2, _ = KV.write_prefill(ck, ck, k, k, window=M)
    # write pos=6 -> slot 6%4=2, evicting pos 2 (the oldest retained)
    newk = jnp.full((1, 1, 1, 1), 6.0)
    pos = jnp.asarray([6])
    ck3, _ = KV.write_decode(ck2, ck2, newk, newk, pos, window=M)
    got = sorted(np.asarray(ck3)[0, :, 0, 0].tolist())
    assert got == [3, 4, 5, 6]


def test_valid_len():
    pos = jnp.asarray([0, 3, 10])
    out = np.asarray(KV.valid_len(pos, max_len=4, window=4))
    np.testing.assert_array_equal(out, [1, 4, 4])


def test_expand_kv_identity_when_equal():
    class Cfg:
        kv_cache_expand_heads = None
        n_kv_heads = 2
    k = _kv(1, 3, 2, 4)
    assert KV.expand_kv_for_cache(Cfg(), k) is k


def test_expand_kv_repeats_heads():
    class Cfg:
        kv_cache_expand_heads = 4
        n_kv_heads = 2
    k = _kv(1, 3, 2, 4)
    out = KV.expand_kv_for_cache(Cfg(), k)
    assert out.shape == (1, 3, 4, 4)
    np.testing.assert_array_equal(np.asarray(out[:, :, 0]),
                                  np.asarray(out[:, :, 1]))
    np.testing.assert_array_equal(np.asarray(out[:, :, 0]),
                                  np.asarray(k[:, :, 0]))
