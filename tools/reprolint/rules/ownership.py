"""thread-ownership: declared ownership domains for engine/pool state.

The pool's concurrency contract ("replica state strictly thread-private,
results joined in replica order", pool.py) lives in annotations this
rule enforces.  Classes declare:

``_THREAD_OWNERSHIP = {"attr": domain, ...}``
    * ``"replica-private"`` — owned by the replica's worker thread while
      it runs; nothing may touch it through another object reference
      from code that runs concurrently with workers.
    * ``"join-only"`` — mutated only by the coordinator at/after the
      join barrier; worker-side mutation is flagged.
    * ``"shared-lock:<lockattr>"`` — every access must be inside
      ``with self.<lockattr>:`` (``__init__`` is exempt: construction
      happens-before publication).

``_WORKER_METHODS = ("step", ...)``
    Methods that run on worker threads.  The set is closed transitively
    over ``self.x()`` calls: a helper called from a worker method is
    worker code too.

``_CONCURRENT_METHODS = ("step", ...)``
    Coordinator methods during which worker threads are live (they
    submit and join workers).  Checked for cross-object
    replica-private access like worker methods, but **not** closed
    transitively — their helpers run after the join barrier by
    contract.

Modules declare ``_MODULE_OWNERSHIP = {"_NAME": "shared-lock:_LOCK"}``
for module-level shared state; all access outside ``with _LOCK:``
(except the defining assignment) is flagged.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..core import Finding, Module, RunContext, dotted_name

DOMAINS = ("replica-private", "join-only")
_MUTATORS = frozenset({
    "append", "extend", "add", "discard", "remove", "pop", "popitem",
    "clear", "update", "setdefault", "insert", "appendleft", "extendleft",
    "sort"})


def _str_dict(node: ast.AST) -> Optional[Dict[str, str]]:
    if not isinstance(node, ast.Dict):
        return None
    out: Dict[str, str] = {}
    for k, v in zip(node.keys, node.values):
        if (isinstance(k, ast.Constant) and isinstance(k.value, str)
                and isinstance(v, ast.Constant)
                and isinstance(v.value, str)):
            out[k.value] = v.value
        else:
            return None
    return out


def _str_tuple(node: ast.AST) -> Optional[Tuple[str, ...]]:
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, str):
                out.append(elt.value)
            else:
                return None
        return tuple(out)
    return None


def _valid_domain(domain: str) -> bool:
    return domain in DOMAINS or (domain.startswith("shared-lock:")
                                 and len(domain) > len("shared-lock:"))


class _ClassDecl:
    def __init__(self, node: ast.ClassDef):
        self.node = node
        self.ownership: Dict[str, str] = {}
        self.decl_line = node.lineno
        self.worker_methods: Tuple[str, ...] = ()
        self.concurrent_methods: Tuple[str, ...] = ()
        self.methods: Dict[str, ast.AST] = {}
        for item in node.body:
            if isinstance(item, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.methods[item.name] = item
            elif isinstance(item, ast.Assign) and len(item.targets) == 1:
                name = dotted_name(item.targets[0])
                if name == "_THREAD_OWNERSHIP":
                    self.ownership = _str_dict(item.value) or {}
                    self.decl_line = item.lineno
                elif name == "_WORKER_METHODS":
                    self.worker_methods = _str_tuple(item.value) or ()
                elif name == "_CONCURRENT_METHODS":
                    self.concurrent_methods = _str_tuple(item.value) or ()

    def worker_closure(self) -> Set[str]:
        """Worker methods plus everything they reach via self.x()."""
        out = set(self.worker_methods)
        frontier = list(out)
        while frontier:
            m = frontier.pop()
            fn = self.methods.get(m)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if isinstance(node, ast.Call):
                    name = dotted_name(node.func)
                    if (name is not None and name.startswith("self.")
                            and "." not in name[5:]):
                        callee = name[5:]
                        if callee in self.methods and callee not in out:
                            out.add(callee)
                            frontier.append(callee)
        return out


def _iter_class_decls(mod: Module) -> Iterable[_ClassDecl]:
    for node in ast.walk(mod.tree):
        if isinstance(node, ast.ClassDef):
            decl = _ClassDecl(node)
            if decl.ownership or decl.worker_methods or \
                    decl.concurrent_methods:
                yield decl


class _LockWalker:
    """Walk a statement list tracking which lock expressions are held
    (``with self._lock:`` / ``with _LOCK:``), invoking ``visit(node,
    held)`` on every expression-level AST node."""

    def __init__(self, visit):
        self.visit = visit

    def walk_stmts(self, stmts, held: Tuple[str, ...]) -> None:
        for stmt in stmts:
            self.walk(stmt, held)

    def walk(self, node: ast.AST, held: Tuple[str, ...]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            # nested defs may run later (callbacks); treat as no-lock
            # context but keep scanning their bodies
            for child in ast.iter_child_nodes(node):
                self.walk(child, ())
            return
        if isinstance(node, (ast.With, ast.AsyncWith)):
            acquired = []
            for item in node.items:
                name = dotted_name(item.context_expr)
                if name is not None:
                    acquired.append(name)
                self.walk(item.context_expr, held)
            inner = held + tuple(acquired)
            self.walk_stmts(node.body, inner)
            return
        self.visit(node, held)
        for child in ast.iter_child_nodes(node):
            self.walk(child, held)


class OwnershipRule:
    name = "thread-ownership"
    description = ("attribute access crossing a declared ownership "
                   "domain (_THREAD_OWNERSHIP / _MODULE_OWNERSHIP): "
                   "worker-side mutation of join-only state, lock-free "
                   "access to shared-lock state, cross-object access "
                   "to replica-private state while workers are live")

    def collect(self, mod: Module, ctx: RunContext) -> None:
        for decl in _iter_class_decls(mod):
            for attr, domain in decl.ownership.items():
                if domain == "replica-private":
                    ctx.ownership_replica_private[attr] = decl.node.name

    def check(self, mod: Module, ctx: RunContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        self._check_module_ownership(mod, findings)
        for decl in _iter_class_decls(mod):
            self._check_class(mod, ctx, decl, findings)
        return findings

    # -- module-level shared state ------------------------------------

    def _check_module_ownership(self, mod: Module,
                                findings: List[Finding]) -> None:
        decl_map: Dict[str, str] = {}
        decl_line = 0
        for stmt in mod.tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                    and dotted_name(stmt.targets[0]) == "_MODULE_OWNERSHIP":
                decl_map = _str_dict(stmt.value) or {}
                decl_line = stmt.lineno
        if not decl_map:
            return
        locks: Dict[str, str] = {}
        for name, domain in decl_map.items():
            if not domain.startswith("shared-lock:"):
                findings.append(Finding(
                    self.name, mod.path, decl_line, "error",
                    f"_MODULE_OWNERSHIP[{name!r}]: unsupported domain "
                    f"{domain!r} (module-level state must be "
                    "'shared-lock:<LOCK>')"))
                continue
            locks[name] = domain.split(":", 1)[1]
        if not locks:
            return
        # the defining top-level assignment is exempt
        defining: Set[int] = set()
        for stmt in mod.tree.body:
            targets: List[ast.AST] = []
            if isinstance(stmt, ast.Assign):
                targets = list(stmt.targets)
            elif isinstance(stmt, ast.AnnAssign):
                targets = [stmt.target]
            for t in targets:
                if dotted_name(t) in locks:
                    defining.add(id(stmt))

        def visit(node: ast.AST, held: Tuple[str, ...]) -> None:
            if isinstance(node, ast.Name) and node.id in locks:
                if locks[node.id] not in held:
                    findings.append(Finding(
                        self.name, mod.path, node.lineno, "error",
                        f"'{node.id}' is shared-lock state: access it "
                        f"inside 'with {locks[node.id]}:' "
                        "(declared in _MODULE_OWNERSHIP)"))

        walker = _LockWalker(visit)
        for stmt in mod.tree.body:
            if id(stmt) in defining:
                continue
            walker.walk(stmt, ())

    # -- class-level ownership ----------------------------------------

    def _check_class(self, mod: Module, ctx: RunContext, decl: _ClassDecl,
                     findings: List[Finding]) -> None:
        for attr, domain in decl.ownership.items():
            if not _valid_domain(domain):
                findings.append(Finding(
                    self.name, mod.path, decl.decl_line, "error",
                    f"_THREAD_OWNERSHIP[{attr!r}]: unknown domain "
                    f"{domain!r} (expected 'replica-private', "
                    "'join-only' or 'shared-lock:<lockattr>')"))
        shared: Dict[str, str] = {
            a: d.split(":", 1)[1] for a, d in decl.ownership.items()
            if d.startswith("shared-lock:") and _valid_domain(d)}
        join_only = {a for a, d in decl.ownership.items()
                     if d == "join-only"}
        workers = decl.worker_closure()
        concurrent = set(decl.concurrent_methods)

        for mname, fn in decl.methods.items():
            in_worker = mname in workers
            in_concurrent = mname in concurrent
            is_init = mname == "__init__"

            def visit(node: ast.AST, held: Tuple[str, ...],
                      _w=in_worker, _c=in_concurrent, _i=is_init) -> None:
                # shared-lock self attrs: lock must be held everywhere
                if (not _i and isinstance(node, ast.Attribute)
                        and isinstance(node.value, ast.Name)
                        and node.value.id == "self"
                        and node.attr in shared):
                    lock = "self." + shared[node.attr]
                    if lock not in held:
                        findings.append(Finding(
                            self.name, mod.path, node.lineno, "error",
                            f"'self.{node.attr}' is shared-lock state: "
                            f"access it inside 'with {lock}:'"))
                if _w:
                    self._check_worker_node(mod, node, join_only,
                                            findings)
                if (_w or _c):
                    self._check_cross_object(mod, ctx, node, findings)

            _LockWalker(visit).walk_stmts(fn.body, ())

    def _check_worker_node(self, mod: Module, node: ast.AST,
                           join_only: Set[str],
                           findings: List[Finding]) -> None:
        def self_attr(n: ast.AST) -> Optional[str]:
            # self.attr, possibly under a subscript (self.attr[i])
            if isinstance(n, ast.Subscript):
                n = n.value
            if isinstance(n, ast.Attribute) and isinstance(
                    n.value, ast.Name) and n.value.id == "self":
                return n.attr
            return None

        flagged: Optional[Tuple[str, int, str]] = None
        if isinstance(node, (ast.Assign, ast.AugAssign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) else \
                [node.target]
            for t in targets:
                attr = self_attr(t)
                if attr in join_only:
                    flagged = (attr, t.lineno, "assigned")
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                attr = self_attr(t)
                if attr in join_only:
                    flagged = (attr, t.lineno, "deleted")
        elif isinstance(node, ast.Call) and isinstance(
                node.func, ast.Attribute) and node.func.attr in _MUTATORS:
            attr = self_attr(node.func.value)
            if attr in join_only:
                flagged = (attr, node.lineno,
                           f"mutated via .{node.func.attr}()")
        if flagged is not None:
            attr, lineno, how = flagged
            findings.append(Finding(
                self.name, mod.path, lineno, "error",
                f"'self.{attr}' is join-only state {how} from a "
                "worker-thread method; mutate it at/after the join "
                "barrier instead"))

    def _check_cross_object(self, mod: Module, ctx: RunContext,
                            node: ast.AST,
                            findings: List[Finding]) -> None:
        if not isinstance(node, ast.Attribute):
            return
        if node.attr not in ctx.ownership_replica_private:
            return
        base = dotted_name(node.value)
        if base in ("self", "cls"):
            return
        owner = ctx.ownership_replica_private[node.attr]
        findings.append(Finding(
            self.name, mod.path, node.lineno, "error",
            f"'.{node.attr}' is replica-private state of {owner}, "
            f"accessed through '{base or '<expr>'}' while worker "
            "threads may be live; route it through the owning "
            "replica's worker or move it past the join barrier"))
