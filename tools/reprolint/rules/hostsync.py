"""host-sync-in-hot-path: device→host transfers in decode/pump loops.

Functions marked ``# reprolint: hot`` (and their nested ``def``s) are
the per-token/per-pass loops where an accidental
``np.asarray``/``.item()``/``float()`` on a JAX value serializes the
device pipeline.  The rule flags, inside hot functions only:

* ``np.asarray`` / ``np.array`` / ``jax.device_get`` — unless the
  argument is a host-side literal (list/tuple display or
  comprehension), which builds an array *from* host data rather than
  pulling one off the device;
* zero-arg ``.item()`` / ``.tolist()`` / ``.block_until_ready()``;
* ``float(...)`` / ``int(...)`` whose argument contains a ``jnp.*`` or
  ``jax.*`` call (forcing the traced value to host).

Deliberate syncs — the one host transfer per decode step — stay, with
``# reprolint: disable=host-sync-in-hot-path -- <why>``.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional

from ..core import Finding, Module, RunContext, call_name

_TRANSFER_CALLS = {"np.asarray", "np.array", "numpy.asarray",
                   "numpy.array", "jax.device_get"}
_SYNC_METHODS = {"item", "tolist", "block_until_ready"}
_HOST_LITERALS = (ast.List, ast.Tuple, ast.ListComp, ast.SetComp,
                  ast.DictComp, ast.GeneratorExp, ast.Dict, ast.Set)


def _contains_jax_call(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Call):
            name = call_name(sub)
            if name is not None and (name.startswith("jnp.")
                                     or name.startswith("jax.")):
                return True
    return False


class HostSyncRule:
    name = "host-sync-in-hot-path"
    description = ("device->host sync (np.asarray / .item() / float() "
                   "on a JAX value) inside a '# reprolint: hot' "
                   "function; sanctioned syncs carry a justified "
                   "suppression")

    def check(self, mod: Module, ctx: RunContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and mod.is_hot(node):
                self._check_hot(mod, node, findings)
        return findings

    def _check_hot(self, mod: Module, fn: ast.AST,
                   findings: List[Finding]) -> None:
        def scan(node: ast.AST) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    continue  # nested hot defs are visited on their own
                if isinstance(child, ast.Call):
                    msg = self._sync_message(child)
                    if msg is not None:
                        findings.append(Finding(
                            self.name, mod.path, child.lineno, "error",
                            msg + " in hot function "
                            f"'{fn.name}'; hoist it out of the loop or "
                            "suppress with a justification if this is "
                            "the deliberate sync point"))
                scan(child)

        scan(fn)

    def _sync_message(self, call: ast.Call) -> Optional[str]:
        name = call_name(call)
        if name in _TRANSFER_CALLS:
            if call.args and isinstance(call.args[0], _HOST_LITERALS):
                return None  # building an array from host data
            return f"'{name}' forces a device->host transfer"
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _SYNC_METHODS \
                and not call.args and not call.keywords:
            return (f"'.{call.func.attr}()' blocks on a device->host "
                    "sync")
        if name in ("float", "int") and call.args \
                and any(_contains_jax_call(a) for a in call.args):
            return (f"'{name}(...)' on a JAX computation forces a "
                    "device->host sync")
        return None
