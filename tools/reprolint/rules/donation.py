"""donation-discipline: use-after-donate detection.

``jax.jit(..., donate_argnums=...)`` invalidates the donated argument
buffers at the call — any later read of the same binding observes a
deleted buffer (an error on TPU, silent aliasing hazards elsewhere).
The engine's step callables are reached through factories
(``_jit_steps`` is an ``lru_cache``'d factory returning a
``(decode, prefill)`` tuple; ``_jit_copy`` caches per-width donating
copies in a module dict), so the rule resolves donation specs through:

* direct bindings: ``step = jax.jit(f, donate_argnums=(0, 1))``
* factory returns: a function whose ``return`` is a donating
  ``jax.jit`` call, a local bound to one (the ``_jit_copy`` dict-cache
  shape), a tuple of donating jits, or a call to another known factory
  (``self._steps()`` → ``_jit_steps`` resolves through the enclosing
  class's method table)
* immediate calls: ``_jit_copy(width)(cache, ...)``

Within each function the rule tracks which bindings (locals and
``self.x`` attribute chains) are dead after a donating call and flags
any read before the binding is stored again.  Reassignment *from the
jit result in the same statement* — the idiomatic pattern — revives
the binding and never fires.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from ..core import (Finding, Module, RunContext, call_name, dotted_name,
                    int_tuple, keyword_arg)

# spec: ("single", positions) or ("tuple", (positions|None, ...))
Spec = Tuple[str, tuple]


def _jit_donate_positions(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """positions for a ``jax.jit(..., donate_argnums=...)`` call."""
    if not isinstance(node, ast.Call):
        return None
    if call_name(node) not in ("jax.jit", "jit"):
        return None
    kw = keyword_arg(node, "donate_argnums")
    if kw is None:
        return None
    return int_tuple(kw)


def _own_statements(func: ast.AST) -> Iterable[ast.stmt]:
    """Statements of ``func`` recursively, not descending into nested
    function/class definitions."""
    stack = list(getattr(func, "body", []))
    while stack:
        stmt = stack.pop(0)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        yield stmt
        for attr in ("body", "orelse", "finalbody"):
            stack.extend(getattr(stmt, attr, []))
        for h in getattr(stmt, "handlers", []):
            stack.extend(h.body)


class _ModuleIndex:
    """Per-module factory/donor resolution tables."""

    def __init__(self, mod: Module):
        self.mod = mod
        # plain function name -> FunctionDef; (class, method) -> FunctionDef
        self.functions: Dict[str, ast.AST] = {}
        self.methods: Dict[Tuple[str, str], ast.AST] = {}
        self.enclosing_class: Dict[ast.AST, str] = {}
        # resolved donation specs for factories / module-level donors
        self.factory_specs: Dict[ast.AST, Spec] = {}
        self.module_donors: Dict[str, Tuple[int, ...]] = {}
        self._build()

    def _build(self) -> None:
        tree = self.mod.tree
        for node in ast.walk(tree):
            if isinstance(node, ast.ClassDef):
                for item in node.body:
                    if isinstance(item, (ast.FunctionDef,
                                         ast.AsyncFunctionDef)):
                        self.methods[(node.name, item.name)] = item
                        self.enclosing_class[item] = node.name
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.functions.setdefault(node.name, node)
        # module-level direct donors: name = jax.jit(..., donate_argnums=..)
        for stmt in tree.body:
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                pos = _jit_donate_positions(stmt.value)
                name = dotted_name(stmt.targets[0])
                if pos is not None and name is not None:
                    self.module_donors[name] = pos
        # fixpoint over factory specs (factories may call factories)
        all_funcs = list(self.functions.values()) + list(
            self.methods.values())
        for _ in range(6):
            changed = False
            for fn in all_funcs:
                if fn in self.factory_specs:
                    continue
                spec = self._factory_spec(fn)
                if spec is not None:
                    self.factory_specs[fn] = spec
                    changed = True
            if not changed:
                break

    # -- factory spec resolution --------------------------------------

    def resolve_callee(self, func_expr: ast.AST,
                       cls: Optional[str]) -> Optional[ast.AST]:
        """Resolve a call's func expression to a FunctionDef: plain
        ``name(...)`` or ``self.name(...)`` within class ``cls``."""
        name = dotted_name(func_expr)
        if name is None:
            return None
        if name.startswith("self.") and cls is not None:
            return self.methods.get((cls, name[5:]))
        if "." not in name:
            return self.functions.get(name)
        return None

    def _expr_spec(self, expr: ast.AST, local_jits: Dict[str, Spec],
                   cls: Optional[str]) -> Optional[Spec]:
        pos = _jit_donate_positions(expr)
        if pos is not None:
            return ("single", pos)
        if isinstance(expr, ast.Name) and expr.id in local_jits:
            return local_jits[expr.id]
        if isinstance(expr, ast.Tuple):
            parts: List[Optional[tuple]] = []
            any_donating = False
            for elt in expr.elts:
                sub = self._expr_spec(elt, local_jits, cls)
                if sub is not None and sub[0] == "single":
                    parts.append(sub[1])
                    any_donating = True
                else:
                    parts.append(None)
            if any_donating:
                return ("tuple", tuple(parts))
            return None
        if isinstance(expr, ast.Call):
            target = self.resolve_callee(expr.func, cls)
            if target is not None and target in self.factory_specs:
                return self.factory_specs[target]
        return None

    def _factory_spec(self, fn: ast.AST) -> Optional[Spec]:
        cls = self.enclosing_class.get(fn)
        local_jits: Dict[str, Spec] = {}
        returns: List[ast.Return] = []
        for stmt in _own_statements(fn):
            if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1:
                name = dotted_name(stmt.targets[0])
                spec = self._expr_spec(stmt.value, local_jits, cls)
                if name is not None and spec is not None:
                    local_jits[name] = spec
            elif isinstance(stmt, ast.Return) and stmt.value is not None:
                returns.append(stmt)
        for ret in returns:
            spec = self._expr_spec(ret.value, local_jits, cls)
            if spec is not None:
                return spec
        return None


class DonationRule:
    name = "donation-discipline"
    description = ("read of a jax.jit-donated buffer binding after the "
                   "donating call, before reassignment (use-after-donate)")

    def check(self, mod: Module, ctx: RunContext) -> Iterable[Finding]:
        if mod.tree is None:
            return []
        index = _ModuleIndex(mod)
        findings: List[Finding] = []
        # every function body is an independent scope; module level too
        scopes: List[Tuple[Optional[ast.AST], Sequence[ast.stmt]]] = [
            (None, [s for s in mod.tree.body
                    if not isinstance(s, (ast.FunctionDef,
                                          ast.AsyncFunctionDef,
                                          ast.ClassDef))])]
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scopes.append((node, node.body))
        for func, body in scopes:
            self._check_scope(mod, index, func, body, findings)
        return findings

    # -- per-scope linear simulation ----------------------------------

    def _check_scope(self, mod: Module, index: _ModuleIndex,
                     func: Optional[ast.AST], body: Sequence[ast.stmt],
                     findings: List[Finding]) -> None:
        cls = index.enclosing_class.get(func) if func is not None else None
        donors: Dict[str, Tuple[int, ...]] = dict(index.module_donors)
        dead: Dict[str, Tuple[str, int]] = {}

        def donating_positions(call: ast.Call) -> Optional[
                Tuple[str, Tuple[int, ...]]]:
            fname = dotted_name(call.func)
            if fname is not None and fname in donors:
                return fname, donors[fname]
            # immediate call of a factory or inline jit:
            #   _jit_copy(w)(cache, ...) / jax.jit(f, donate...)(x)
            if isinstance(call.func, ast.Call):
                inner = call.func
                pos = _jit_donate_positions(inner)
                if pos is not None:
                    return call_name(inner) or "jax.jit(...)", pos
                target = index.resolve_callee(inner.func, cls)
                spec = index.factory_specs.get(target)
                if spec is not None and spec[0] == "single":
                    return (dotted_name(inner.func) or "<factory>",
                            spec[1])
            return None

        def bind_from_value(targets: Sequence[ast.AST],
                            value: ast.AST) -> None:
            """Track donor bindings created by this assignment."""
            spec = None
            pos = _jit_donate_positions(value)
            if pos is not None:
                spec = ("single", pos)
            elif isinstance(value, ast.Call):
                target_fn = index.resolve_callee(value.func, cls)
                spec = index.factory_specs.get(target_fn)
            if spec is None:
                return
            if spec[0] == "single" and len(targets) == 1:
                name = dotted_name(targets[0])
                if name is not None:
                    donors[name] = spec[1]
            elif spec[0] == "tuple" and len(targets) == 1 and isinstance(
                    targets[0], ast.Tuple):
                for elt, part in zip(targets[0].elts, spec[1]):
                    if part is None:
                        continue
                    name = dotted_name(elt)
                    if name is not None:
                        donors[name] = part

        def loads_in(node: ast.AST) -> Iterable[Tuple[str, int]]:
            for sub in ast.walk(node):
                if isinstance(sub, (ast.Name, ast.Attribute)) and isinstance(
                        getattr(sub, "ctx", None), ast.Load):
                    name = dotted_name(sub)
                    if name is not None:
                        yield name, sub.lineno

        def stores_in(stmt: ast.stmt) -> List[str]:
            out: List[str] = []

            def add_target(t: ast.AST) -> None:
                if isinstance(t, (ast.Tuple, ast.List)):
                    for elt in t.elts:
                        add_target(elt)
                    return
                name = dotted_name(t)
                if name is not None:
                    out.append(name)

            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    add_target(t)
            elif isinstance(stmt, (ast.AugAssign, ast.AnnAssign)):
                add_target(stmt.target)
            elif isinstance(stmt, ast.For):
                add_target(stmt.target)
            return out

        def check_loads(node: ast.AST) -> None:
            """Reads against bindings donated by earlier statements."""
            if not dead:
                return
            for name, lineno in loads_in(node):
                hit_key = name if name in dead else None
                # "self.cache" dead also kills "self.cache.anything"
                if hit_key is None:
                    for d in dead:
                        if name.startswith(d + "."):
                            hit_key = d
                            break
                if hit_key is not None:
                    callee, dline = dead.pop(hit_key)  # one report each
                    via = "" if name == hit_key else f" (via '{name}')"
                    findings.append(Finding(
                        self.name, mod.path, lineno, "error",
                        f"'{hit_key}' was donated to '{callee}' (line "
                        f"{dline}) and read{via} before reassignment; "
                        "rebind it from the jit result first"))

        def apply_donations(node: ast.AST) -> None:
            for sub in ast.walk(node):
                if isinstance(sub, ast.Call):
                    hit = donating_positions(sub)
                    if hit is None:
                        continue
                    callee, positions = hit
                    for p in positions:
                        if p < len(sub.args):
                            name = dotted_name(sub.args[p])
                            if name is not None:
                                dead[name] = (callee, sub.lineno)

        COMPOUND = (ast.If, ast.For, ast.AsyncFor, ast.While, ast.With,
                    ast.AsyncWith, ast.Try)

        def visit(stmt: ast.stmt) -> None:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.ClassDef)):
                return  # nested scopes analyzed independently
            if isinstance(stmt, COMPOUND):
                # only the header expressions execute before the body
                headers: List[ast.AST] = []
                if isinstance(stmt, (ast.If, ast.While)):
                    headers = [stmt.test]
                elif isinstance(stmt, (ast.For, ast.AsyncFor)):
                    headers = [stmt.iter]
                elif isinstance(stmt, (ast.With, ast.AsyncWith)):
                    headers = [i.context_expr for i in stmt.items]
                for h in headers:
                    check_loads(h)
                    apply_donations(h)
                if isinstance(stmt, (ast.For, ast.AsyncFor)):
                    name = dotted_name(stmt.target)
                    if name is not None:
                        dead.pop(name, None)
                for attr in ("body", "orelse", "finalbody"):
                    for s in getattr(stmt, attr, []):
                        visit(s)
                for handler in getattr(stmt, "handlers", []):
                    for s in handler.body:
                        visit(s)
                return
            check_loads(stmt)
            apply_donations(stmt)
            if isinstance(stmt, ast.Assign):
                bind_from_value(stmt.targets, stmt.value)
            for name in stores_in(stmt):
                dead.pop(name, None)

        for stmt in body:
            visit(stmt)
