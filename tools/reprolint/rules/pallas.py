"""pallas-contract: structural checks on every ``pl.pallas_call``.

Three contracts the TPU kernels rely on:

1. **Signature arity** — the kernel function must take exactly
   ``num_scalar_prefetch + len(in_specs) + n_outputs +
   len(scratch_shapes)`` positional refs, in that order.  A missing
   scalar-prefetch ref shifts every operand one position and the
   kernel reads garbage (or crashes at trace time with a misleading
   shape error).
2. **Index-map purity** — BlockSpec index maps must be pure index
   arithmetic over ``(grid indices..., scalar-prefetch refs...)``:
   no attribute access, subscripting or calls, and no names captured
   from the enclosing scope.  Captured loop variables are evaluated at
   trace time with their *final* values; the sanctioned idiom binds
   them via lambda defaults (``lambda b, h, j, g=g: ...``).  Arity must
   equal grid rank + num_scalar_prefetch.
3. **Dispatch layering** — code under ``src/`` outside the kernels
   package reaches kernels only through ``repro.kernels.ops`` /
   ``repro.kernels.dispatch`` (or ``.ref``), never by importing a
   kernel implementation module directly: the dispatch layer owns the
   pallas/XLA routing, interpret-mode and compiler-params decisions.

Anything not statically resolvable (dynamic spec lists, kernels built
elsewhere) is skipped, not flagged.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Tuple

from ..core import Finding, Module, RunContext, call_name, dotted_name, \
    keyword_arg

KERNEL_IMPL_MODULES = frozenset({
    "flash_attention", "ragged_prefill_attention",
    "batched_decode_attention", "decode_attention", "chunked_gla",
    "rmsnorm"})
_ALLOWED = frozenset({"ops", "dispatch", "ref"})


def _local_assignments(fn: Optional[ast.AST],
                       tree: ast.AST) -> Dict[str, ast.AST]:
    """name -> value expr for simple assignments in the enclosing
    function (falling back to module level)."""
    out: Dict[str, ast.AST] = {}
    body = getattr(fn, "body", None) or tree.body
    stack = list(body)
    while stack:
        stmt = stack.pop(0)
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
            continue
        if isinstance(stmt, ast.Assign) and len(stmt.targets) == 1 \
                and isinstance(stmt.targets[0], ast.Name):
            out[stmt.targets[0].id] = stmt.value
        for attr in ("body", "orelse", "finalbody"):
            stack.extend(getattr(stmt, attr, []))
        for h in getattr(stmt, "handlers", []):
            stack.extend(h.body)
    return out


class PallasRule:
    name = "pallas-contract"
    description = ("pl.pallas_call structural contracts: kernel "
                   "signature arity vs grid spec (incl. scalar "
                   "prefetch), index-map purity/arity, and kernels "
                   "reached only via the dispatch layer")

    def check(self, mod: Module, ctx: RunContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        self._check_layering(mod, findings)
        module_fns: Dict[str, ast.AST] = {}
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                module_fns.setdefault(node.name, node)
        # find pallas_call sites together with their enclosing function
        def scan(node: ast.AST, fn: Optional[ast.AST]) -> None:
            for child in ast.iter_child_nodes(node):
                child_fn = fn
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    child_fn = child
                if isinstance(child, ast.Call):
                    name = call_name(child)
                    if name is not None and (
                            name == "pallas_call"
                            or name.endswith(".pallas_call")):
                        self._check_site(mod, child, child_fn, module_fns,
                                         findings)
                scan(child, child_fn)

        scan(mod.tree, None)
        return findings

    # -- dispatch layering --------------------------------------------

    def _check_layering(self, mod: Module, findings: List[Finding]) -> None:
        parts = Path(mod.path).parts
        if "repro" not in parts or "kernels" in parts:
            return  # only library code outside the kernels package
        for node in ast.walk(mod.tree):
            bad: Optional[str] = None
            if isinstance(node, ast.Import):
                for alias in node.names:
                    segs = alias.name.split(".")
                    if len(segs) >= 3 and segs[0] == "repro" \
                            and segs[1] == "kernels" \
                            and segs[2] in KERNEL_IMPL_MODULES:
                        bad = alias.name
            elif isinstance(node, ast.ImportFrom) and node.module:
                segs = node.module.split(".")
                if segs[:2] == ["repro", "kernels"]:
                    if len(segs) >= 3 and segs[2] in KERNEL_IMPL_MODULES:
                        bad = node.module
                    elif len(segs) == 2:
                        for alias in node.names:
                            if alias.name in KERNEL_IMPL_MODULES:
                                bad = f"repro.kernels.{alias.name}"
            if bad is not None:
                findings.append(Finding(
                    self.name, mod.path, node.lineno, "error",
                    f"direct import of kernel module '{bad}': library "
                    "code reaches kernels via repro.kernels.ops / "
                    "repro.kernels.dispatch only (the dispatch layer "
                    "owns pallas/XLA routing)"))

    # -- per-site structural checks -----------------------------------

    def _check_site(self, mod: Module, call: ast.Call,
                    fn: Optional[ast.AST], module_fns: Dict[str, ast.AST],
                    findings: List[Finding]) -> None:
        env = _local_assignments(fn, mod.tree)

        def resolve(node: Optional[ast.AST]) -> Optional[ast.AST]:
            seen = 0
            while isinstance(node, ast.Name) and node.id in env \
                    and seen < 5:
                node = env[node.id]
                seen += 1
            return node

        # spec source: grid_spec=... object, or kwargs on the call
        nsp = 0
        spec_src: ast.Call = call
        gs = resolve(keyword_arg(call, "grid_spec"))
        if gs is not None:
            if not isinstance(gs, ast.Call):
                return
            gs_name = call_name(gs) or ""
            if gs_name.endswith("PrefetchScalarGridSpec"):
                nsp_node = resolve(keyword_arg(gs, "num_scalar_prefetch"))
                if isinstance(nsp_node, ast.Constant) and isinstance(
                        nsp_node.value, int):
                    nsp = nsp_node.value
                elif nsp_node is not None:
                    return
            elif not gs_name.endswith("GridSpec"):
                return
            spec_src = gs

        grid = resolve(keyword_arg(spec_src, "grid"))
        grid_rank: Optional[int] = None
        if isinstance(grid, ast.Tuple):
            grid_rank = len(grid.elts)
        elif isinstance(grid, ast.Constant) and isinstance(grid.value, int):
            grid_rank = 1

        lambdas: List[ast.Lambda] = []

        def spec_count(node: Optional[ast.AST]) -> Optional[int]:
            node = resolve(node)
            if node is None:
                return None
            if isinstance(node, (ast.List, ast.Tuple)):
                for elt in node.elts:
                    self._collect_index_map(resolve(elt), lambdas)
                return len(node.elts)
            if isinstance(node, ast.Call):
                self._collect_index_map(node, lambdas)
                return 1
            if isinstance(node, ast.Constant) and node.value is None:
                return 1
            return None

        n_in = spec_count(keyword_arg(spec_src, "in_specs"))
        n_out = spec_count(keyword_arg(spec_src, "out_specs"))
        if n_out is None:
            out_shape = resolve(keyword_arg(call, "out_shape"))
            if isinstance(out_shape, (ast.List, ast.Tuple)):
                n_out = len(out_shape.elts)
            elif out_shape is not None:
                n_out = 1
        scratch = resolve(keyword_arg(spec_src, "scratch_shapes"))
        if scratch is None:
            n_scratch: Optional[int] = 0
        elif isinstance(scratch, (ast.List, ast.Tuple)):
            n_scratch = len(scratch.elts)
        else:
            n_scratch = None

        # kernel signature arity
        kernel = resolve(call.args[0]) if call.args else None
        extra_positional = 0
        if isinstance(kernel, ast.Call) and (
                call_name(kernel) or "").endswith("partial"):
            extra_positional = max(0, len(kernel.args) - 1)
            kernel = resolve(kernel.args[0]) if kernel.args else None
        kernel_def = None
        kernel_name = None
        if isinstance(kernel, ast.Name):
            kernel_name = kernel.id
            kernel_def = module_fns.get(kernel.id)
        if kernel_def is not None and None not in (n_in, n_out, n_scratch):
            args = kernel_def.args
            n_params = len(args.posonlyargs) + len(args.args) \
                - extra_positional
            n_defaults = len(args.defaults)
            expected = nsp + n_in + n_out + n_scratch
            if not (n_params - n_defaults <= expected <= n_params):
                findings.append(Finding(
                    self.name, mod.path, call.lineno, "error",
                    f"kernel '{kernel_name}' takes {n_params} positional "
                    f"refs but this pallas_call supplies {expected} "
                    f"(scalar_prefetch={nsp} + in_specs={n_in} + "
                    f"outputs={n_out} + scratch={n_scratch}); a "
                    "mismatched scalar-prefetch count shifts every "
                    "operand ref"))

        # index-map arity + purity
        for lam in lambdas:
            self._check_index_map(mod, lam, grid_rank, nsp, findings)

    def _collect_index_map(self, node: Optional[ast.AST],
                           lambdas: List[ast.Lambda]) -> None:
        """Pull the index_map lambda out of a BlockSpec(...) call."""
        if not isinstance(node, ast.Call):
            return
        name = call_name(node) or ""
        if not name.endswith("BlockSpec"):
            return
        cand = None
        if len(node.args) >= 2:
            cand = node.args[1]
        kw = keyword_arg(node, "index_map")
        if kw is not None:
            cand = kw
        if isinstance(cand, ast.Lambda):
            lambdas.append(cand)

    def _check_index_map(self, mod: Module, lam: ast.Lambda,
                         grid_rank: Optional[int], nsp: int,
                         findings: List[Finding]) -> None:
        args = lam.args
        params = [a.arg for a in args.posonlyargs + args.args]
        n_required = len(params) - len(args.defaults)
        if grid_rank is not None and n_required != grid_rank + nsp:
            findings.append(Finding(
                self.name, mod.path, lam.lineno, "error",
                f"index map takes {n_required} required args but the "
                f"grid supplies {grid_rank} indices + {nsp} scalar-"
                "prefetch refs; bind captured values via lambda "
                "defaults, not extra parameters"))
        allowed = set(params)
        for sub in ast.walk(lam.body):
            if isinstance(sub, (ast.Attribute, ast.Subscript, ast.Call)):
                findings.append(Finding(
                    self.name, mod.path, sub.lineno, "error",
                    "index map must be pure index arithmetic: no "
                    "attribute access, subscripting or calls (hoist "
                    "the value and bind it via a lambda default)"))
                break
            if isinstance(sub, ast.Name) and sub.id not in allowed:
                findings.append(Finding(
                    self.name, mod.path, sub.lineno, "error",
                    f"index map captures '{sub.id}' from the enclosing "
                    "scope; trace-time capture sees the final loop "
                    "value — bind it via a default "
                    f"(lambda ..., {sub.id}={sub.id}: ...)"))
                break
