"""Rule registry.  Each rule object exposes ``name``, ``description``,
an optional ``collect(module, ctx)`` pre-pass and a
``check(module, ctx) -> Iterable[Finding]`` pass."""
from .donation import DonationRule
from .hostsync import HostSyncRule
from .ownership import OwnershipRule
from .pallas import PallasRule
from .retrace import RetraceRule

ALL_RULES = [
    DonationRule(),
    OwnershipRule(),
    RetraceRule(),
    HostSyncRule(),
    PallasRule(),
]

RULE_NAMES = tuple(r.name for r in ALL_RULES)

__all__ = ["ALL_RULES", "RULE_NAMES", "DonationRule", "HostSyncRule",
           "OwnershipRule", "PallasRule", "RetraceRule"]
