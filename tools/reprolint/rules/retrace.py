"""retrace-hazard: jit construction and cache-key hygiene.

Two hazards, both of which melt the bounded-retrace contract (the
bucket ladder caps distinct traced shapes; PR 5):

1. ``jax.jit(...)`` called inside a loop or a hot (per-step/per-request)
   function.  Every such call builds a fresh traced callable — the
   compile cache is keyed by the callable object, so this retraces
   every time.  Step callables belong in a cached factory
   (``functools.lru_cache``'d like ``_jit_steps``, or a module-level
   dict like ``_COPY_JITS``); functions decorated with ``lru_cache`` /
   ``cache`` are exempt since the construction itself is cached.

2. Unstable values flowing into jit/step-factory cache keys: an
   f-string, list/dict/set display or comprehension, or ``list()`` /
   ``dict()`` / ``set()`` call passed as an argument to an
   ``lru_cache``'d function in the same module.  Unhashables raise at
   runtime; per-call-unique strings silently defeat the cache and
   unbound the retrace count.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Set

from ..core import Finding, Module, RunContext, call_name, dotted_name

_CACHE_DECORATORS = {"functools.lru_cache", "lru_cache",
                     "functools.cache", "cache"}
_UNSTABLE_BUILDERS = {"list", "dict", "set"}


def _is_cache_decorated(fn: ast.AST) -> bool:
    for dec in getattr(fn, "decorator_list", []):
        name = dotted_name(dec.func if isinstance(dec, ast.Call) else dec)
        if name in _CACHE_DECORATORS:
            return True
    return False


def _unstable_arg(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.JoinedStr):
        return "an f-string (per-call-unique cache key)"
    if isinstance(node, (ast.List, ast.ListComp)):
        return "a list (unhashable cache key)"
    if isinstance(node, (ast.Dict, ast.DictComp)):
        return "a dict (unhashable cache key)"
    if isinstance(node, (ast.Set, ast.SetComp)):
        return "a set (unhashable cache key)"
    if isinstance(node, ast.GeneratorExp):
        return "a generator (unhashable cache key)"
    if isinstance(node, ast.Call) and call_name(node) in _UNSTABLE_BUILDERS:
        return f"a {call_name(node)}() result (unhashable cache key)"
    return None


class RetraceRule:
    name = "retrace-hazard"
    description = ("jax.jit constructed per-call (in a loop or hot "
                   "function) instead of via a cached step factory; "
                   "unhashable or per-call-unique values into an "
                   "lru_cache'd factory's cache key")

    def check(self, mod: Module, ctx: RunContext) -> Iterable[Finding]:
        findings: List[Finding] = []
        cached_fns: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and _is_cache_decorated(node):
                cached_fns.add(node.name)

        def scan(node: ast.AST, in_loop: bool, hot: bool,
                 exempt: bool) -> None:
            for child in ast.iter_child_nodes(node):
                c_loop, c_hot, c_exempt = in_loop, hot, exempt
                if isinstance(child, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    # a new function scope: loop context resets, hotness
                    # inherits, cache-decoration exempts the whole body
                    c_loop = False
                    c_hot = hot or mod.is_hot(child)
                    c_exempt = _is_cache_decorated(child)
                elif isinstance(child, (ast.For, ast.AsyncFor, ast.While)):
                    c_loop = True
                elif isinstance(child, ast.Call):
                    self._check_call(mod, child, in_loop, hot, exempt,
                                     cached_fns, findings)
                scan(child, c_loop, c_hot, c_exempt)

        scan(mod.tree, False, False, False)
        return findings

    def _check_call(self, mod: Module, node: ast.Call, in_loop: bool,
                    hot: bool, exempt: bool, cached_fns: Set[str],
                    findings: List[Finding]) -> None:
        name = call_name(node)
        if name in ("jax.jit", "jit") and not exempt and (in_loop or hot):
            where = "inside a loop" if in_loop else "in a hot function"
            findings.append(Finding(
                self.name, mod.path, node.lineno, "error",
                f"jax.jit constructed {where}: each call builds a fresh "
                "traced callable and retraces; hoist it into a cached "
                "step factory (lru_cache / module-level dict)"))
            return
        if name is None:
            return
        callee = name[5:] if name.startswith("self.") else name
        if callee in cached_fns and "." not in callee:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                why = _unstable_arg(arg)
                if why is not None:
                    findings.append(Finding(
                        self.name, mod.path, arg.lineno, "error",
                        f"'{callee}' is lru_cache'd but receives {why}; "
                        "cache keys must be stable hashables or the "
                        "retrace/compile count is unbounded"))
