"""Framework core for reprolint: file walking, directive parsing, the
``Finding`` model, suppression/baseline semantics and the two-phase
rule runner.

Directives
----------
Two comment directives are recognised, either trailing on a line or on
a comment-only line immediately above the line they govern:

``# reprolint: disable=<rule>[,<rule>...] -- <justification>``
    Suppress the named rule(s) on the governed line.  The justification
    is **mandatory**: a disable directive without ``-- <reason>`` (or
    naming an unknown rule) is itself reported as a ``reprolint-directive``
    error, and the suppression does not take effect.

``# reprolint: hot``
    Mark the governed ``def`` as a hot path (decode/pump loop).  The
    ``host-sync-in-hot-path`` and ``retrace-hazard`` rules only inspect
    hot functions; nested ``def``s inherit hotness from their enclosing
    function.

Run model
---------
Rules are objects with a ``name``, a ``collect(module, ctx)`` phase
(run over every module first, so rules may build cross-module context)
and a ``check(module, ctx)`` phase returning ``Finding``s.  Suppression
and baseline filtering happen in the runner, not in the rules.
"""
from __future__ import annotations

import ast
import io
import json
import re
import tokenize
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

SEVERITIES = ("error", "warning")

_DIRECTIVE_RE = re.compile(r"#\s*reprolint:\s*(?P<body>.*\S)?\s*$")
_DISABLE_RE = re.compile(
    r"disable\s*=\s*(?P<rules>[A-Za-z0-9_\-,\s]+?)"
    r"(?:\s+--\s*(?P<reason>.*\S))?$")


@dataclass(frozen=True)
class Finding:
    """One diagnostic: ``rule`` that fired, ``path``/``line`` location,
    ``severity`` ("error" | "warning") and a human message."""

    rule: str
    path: str
    line: int
    severity: str
    message: str

    def key(self) -> Tuple[str, str, str]:
        """Baseline fingerprint: line numbers drift, so the baseline
        matches on (rule, path, message) only."""
        return (self.rule, self.path, self.message)

    def to_json(self) -> dict:
        return {"rule": self.rule, "path": self.path, "line": self.line,
                "severity": self.severity, "message": self.message}

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.severity}] "
                f"{self.rule}: {self.message}")


@dataclass
class Suppression:
    line: int                # line the directive governs
    rules: Tuple[str, ...]
    reason: str
    directive_line: int
    used: bool = False


class Module:
    """One parsed source file plus its reprolint directives."""

    def __init__(self, path: str, source: str,
                 known_rules: Sequence[str] = ()):
        self.path = path
        self.source = source
        self.lines = source.splitlines()
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[Finding] = None
        self.suppressions: Dict[int, List[Suppression]] = {}
        self.hot_lines: Set[int] = set()
        self.directive_findings: List[Finding] = []
        try:
            self.tree = ast.parse(source, filename=path)
        except SyntaxError as exc:
            self.parse_error = Finding(
                "reprolint-parse", path, exc.lineno or 1, "error",
                f"could not parse file: {exc.msg}")
        self._scan_directives(tuple(known_rules))
        self._hot_functions: Optional[Set[ast.AST]] = None

    # -- directive scanning -------------------------------------------

    def _scan_directives(self, known_rules: Tuple[str, ...]) -> None:
        try:
            tokens = list(tokenize.generate_tokens(
                io.StringIO(self.source).readline))
        except (tokenize.TokenError, IndentationError, SyntaxError):
            return
        for tok in tokens:
            if tok.type != tokenize.COMMENT:
                continue
            m = _DIRECTIVE_RE.search(tok.string)
            if m is None:
                continue
            lineno, col = tok.start
            # comment-only line => directive governs the next line
            prefix = self.lines[lineno - 1][:col] if lineno <= len(
                self.lines) else ""
            own_line = prefix.strip() == ""
            governed = lineno + 1 if own_line else lineno
            body = (m.group("body") or "").strip()
            if body == "hot":
                self.hot_lines.add(governed)
                continue
            dm = _DISABLE_RE.match(body)
            if dm is None:
                self.directive_findings.append(Finding(
                    "reprolint-directive", self.path, lineno, "error",
                    f"unrecognised reprolint directive: {body!r} "
                    "(expected 'disable=<rule>[,...] -- <reason>' "
                    "or 'hot')"))
                continue
            rules = tuple(r.strip() for r in dm.group("rules").split(",")
                          if r.strip())
            reason = (dm.group("reason") or "").strip()
            if not reason:
                self.directive_findings.append(Finding(
                    "reprolint-directive", self.path, lineno, "error",
                    "suppression requires a justification: "
                    "'# reprolint: disable=<rule> -- <why this is safe>'"))
                continue
            unknown = [r for r in rules
                       if known_rules and r not in known_rules]
            if unknown:
                self.directive_findings.append(Finding(
                    "reprolint-directive", self.path, lineno, "error",
                    f"unknown rule(s) in disable directive: "
                    f"{', '.join(unknown)}"))
                continue
            self.suppressions.setdefault(governed, []).append(
                Suppression(governed, rules, reason, lineno))

    # -- hot-path marking ---------------------------------------------

    def is_hot(self, func: ast.AST) -> bool:
        """True if ``func`` (a FunctionDef/AsyncFunctionDef) carries a
        ``# reprolint: hot`` marker, or is nested inside one that does.
        The marker may sit on the ``def`` line, on the line governing it
        (comment line above), or above the first decorator."""
        return func in self._hot_function_set()

    def _hot_function_set(self) -> Set[ast.AST]:
        if self._hot_functions is not None:
            return self._hot_functions
        hot: Set[ast.AST] = set()
        if self.tree is not None:
            self._collect_hot(self.tree, False, hot)
        self._hot_functions = hot
        return hot

    def _directly_hot(self, node: ast.AST) -> bool:
        candidates = {node.lineno}
        if getattr(node, "decorator_list", None):
            candidates.add(node.decorator_list[0].lineno)
        return bool(candidates & self.hot_lines)

    def _collect_hot(self, node: ast.AST, inherited: bool,
                     out: Set[ast.AST]) -> None:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                hot = inherited or self._directly_hot(child)
                if hot:
                    out.add(child)
                self._collect_hot(child, hot, out)
            else:
                self._collect_hot(child, inherited, out)

    # -- suppression lookup -------------------------------------------

    def suppressed(self, finding: Finding) -> bool:
        for sup in self.suppressions.get(finding.line, ()):
            if finding.rule in sup.rules:
                sup.used = True
                return True
        return False


class RunContext:
    """Cross-module scratch space shared between collect and check
    phases.  Rules namespace their state by attribute."""

    def __init__(self) -> None:
        self.modules: Dict[str, Module] = {}
        # rule-owned registries (see rules/*.py)
        self.ownership_replica_private: Dict[str, str] = {}


@dataclass
class RunResult:
    findings: List[Finding]
    baseline_hits: int
    n_files: int

    @property
    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for f in self.findings:
            out[f.rule] = out.get(f.rule, 0) + 1
        return out

    @property
    def ok(self) -> bool:
        return not any(f.severity == "error" for f in self.findings)


def iter_python_files(paths: Iterable[str]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        path = Path(p)
        if path.is_dir():
            out.extend(sorted(path.rglob("*.py")))
        elif path.suffix == ".py":
            out.append(path)
    # dedupe, preserve order
    seen: Set[Path] = set()
    uniq = []
    for p in out:
        if p not in seen:
            seen.add(p)
            uniq.append(p)
    return uniq


def load_baseline(path: Optional[Path]) -> Set[Tuple[str, str, str]]:
    """Baseline file: JSON list of ``{"rule", "path", "message"}``
    fingerprints accepted as pre-existing debt.  Ships empty."""
    if path is None or not path.exists():
        return set()
    data = json.loads(path.read_text())
    return {(d["rule"], d["path"], d["message"]) for d in data}


def run(paths: Sequence[str], rules: Sequence[object],
        baseline: Optional[Path] = None,
        sources: Optional[Dict[str, str]] = None) -> RunResult:
    """Analyze ``paths`` (dirs or .py files) under ``rules``.

    ``sources`` maps path -> source text for in-memory analysis (tests);
    when given, ``paths`` entries are looked up there instead of disk.
    """
    known = [r.name for r in rules]
    ctx = RunContext()
    modules: List[Module] = []
    if sources is not None:
        for p in paths:
            mod = Module(p, sources[p], known)
            modules.append(mod)
            ctx.modules[p] = mod
    else:
        for fp in iter_python_files(paths):
            try:
                text = fp.read_text(encoding="utf-8")
            except (OSError, UnicodeDecodeError):
                continue
            mod = Module(str(fp), text, known)
            modules.append(mod)
            ctx.modules[str(fp)] = mod

    findings: List[Finding] = []
    for mod in modules:
        if mod.parse_error is not None:
            findings.append(mod.parse_error)
        findings.extend(mod.directive_findings)

    for rule in rules:
        collect = getattr(rule, "collect", None)
        if collect is not None:
            for mod in modules:
                if mod.tree is not None:
                    collect(mod, ctx)
    for rule in rules:
        for mod in modules:
            if mod.tree is None:
                continue
            for f in rule.check(mod, ctx):
                if not mod.suppressed(f):
                    findings.append(f)

    base = load_baseline(baseline)
    baseline_hits = 0
    if base:
        kept = []
        for f in findings:
            if f.key() in base:
                baseline_hits += 1
            else:
                kept.append(f)
        findings = kept

    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return RunResult(findings, baseline_hits, len(modules))


def analyze_source(source: str, path: str = "<fixture>",
                   rules: Optional[Sequence[object]] = None) -> List[Finding]:
    """Single-source entry point for tests: run all (or the given)
    rules over one in-memory module, no baseline."""
    if rules is None:
        from .rules import ALL_RULES
        rules = ALL_RULES
    return run([path], rules, baseline=None, sources={path: source}).findings


# ---------------------------------------------------------------------
# shared AST helpers used by the rule modules


def dotted_name(node: ast.AST) -> Optional[str]:
    """"a", "a.b.c", "self.cache" — or None for non-name expressions."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def call_name(node: ast.Call) -> Optional[str]:
    return dotted_name(node.func)


def keyword_arg(call: ast.Call, name: str) -> Optional[ast.AST]:
    for kw in call.keywords:
        if kw.arg == name:
            return kw.value
    return None


def int_tuple(node: ast.AST) -> Optional[Tuple[int, ...]]:
    """Literal int or tuple-of-ints, e.g. a donate_argnums value."""
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return (node.value,)
    if isinstance(node, ast.Tuple):
        out = []
        for elt in node.elts:
            if isinstance(elt, ast.Constant) and isinstance(elt.value, int):
                out.append(elt.value)
            else:
                return None
        return tuple(out)
    return None


def parent_map(tree: ast.AST) -> Dict[ast.AST, ast.AST]:
    out: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            out[child] = node
    return out
