"""reprolint — repo-native static analysis for the serving stack.

Five AST-level checkers turn the codebase's concurrency and JAX
contracts into gating CI (see README.md for the rule catalog):

* ``donation-discipline`` — use-after-donate at jit call sites
* ``thread-ownership``    — declared ownership domains for pool state
* ``retrace-hazard``      — per-call jit construction, unstable keys
* ``host-sync-in-hot-path`` — device syncs in decode/pump loops
* ``pallas-contract``     — pallas_call arity / index-map purity /
  dispatch layering

Pure stdlib (``ast`` + ``tokenize``); no JAX import, no device.
Run with ``PYTHONPATH=tools python -m reprolint [paths] [--json]``.
"""
from .core import Finding, Module, RunResult, analyze_source, run
from .rules import ALL_RULES, RULE_NAMES

__version__ = "0.1.0"
__all__ = ["ALL_RULES", "Finding", "Module", "RULE_NAMES", "RunResult",
           "analyze_source", "run"]
