"""Command-line entry point: ``python -m reprolint [paths] [--json]``.

Exit status is 0 when no error-severity findings remain after
suppression and baseline filtering, 1 otherwise.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path
from typing import List, Optional, Sequence

from .core import run
from .rules import ALL_RULES

DEFAULT_PATHS = ("src", "tests", "benchmarks")
DEFAULT_BASELINE = Path(__file__).resolve().parent / "baseline.json"


def build_parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="reprolint",
        description="repo-native static analysis: JAX donation "
                    "discipline, thread ownership, retrace hazards, "
                    "host syncs in hot paths, Pallas kernel contracts")
    p.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                   help="files or directories to analyze "
                        f"(default: {' '.join(DEFAULT_PATHS)})")
    p.add_argument("--json", action="store_true", dest="as_json",
                   help="emit findings as a JSON object on stdout")
    p.add_argument("--baseline", type=Path, default=DEFAULT_BASELINE,
                   help="JSON baseline of accepted findings "
                        "(default: the package's baseline.json; "
                        "ships empty)")
    p.add_argument("--no-baseline", action="store_true",
                   help="ignore the baseline file entirely")
    p.add_argument("--summary", action="store_true",
                   help="append a markdown per-rule count table "
                        "(for CI job summaries)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalog and exit")
    return p


def main(argv: Optional[Sequence[str]] = None) -> int:
    args = build_parser().parse_args(argv)
    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name}: {rule.description}")
        return 0

    baseline = None if args.no_baseline else args.baseline
    result = run(args.paths, ALL_RULES, baseline=baseline)

    if args.as_json:
        print(json.dumps({
            "files": result.n_files,
            "baseline_hits": result.baseline_hits,
            "counts": result.counts,
            "findings": [f.to_json() for f in result.findings],
        }, indent=2))
    else:
        for f in result.findings:
            print(f.render())
        if result.findings:
            print(f"reprolint: {len(result.findings)} finding(s) in "
                  f"{result.n_files} file(s)")
        else:
            print(f"reprolint: clean ({result.n_files} files, "
                  f"{len(ALL_RULES)} rules)")
        if result.baseline_hits:
            print(f"reprolint: {result.baseline_hits} baselined "
                  "finding(s) suppressed")

    if args.summary:
        lines: List[str] = ["", "| rule | findings |", "| --- | --- |"]
        counts = result.counts
        for rule in ALL_RULES:
            lines.append(f"| {rule.name} | {counts.get(rule.name, 0)} |")
        for extra in sorted(set(counts) - {r.name for r in ALL_RULES}):
            lines.append(f"| {extra} | {counts[extra]} |")
        lines.append(f"| **files scanned** | {result.n_files} |")
        print("\n".join(lines))

    return 0 if result.ok else 1


if __name__ == "__main__":
    sys.exit(main())
