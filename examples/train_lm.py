"""End-to-end training driver: train a reduced executor LM on the synthetic
pipeline for a few hundred steps with checkpointing.

    PYTHONPATH=src python examples/train_lm.py --arch qwen2-1.5b \
        --steps 300 --d-model 256 --layers 4

The default config is a ~10M-param reduction that trains on CPU in a few
minutes; pass --full-width for the ~100M-class run on real hardware.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import packed_batches, Prefetcher
from repro.training.loop import train, TrainConfig
from repro.training.optimizer import AdamWConfig
from repro.training import checkpoint as CKPT


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--full-width", action="store_true",
                    help="~100M params (needs accelerator-grade time)")
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    base = get_config(args.arch)
    if args.full_width:
        cfg = base.variant(n_layers=12, d_model=768, n_heads=12,
                           n_kv_heads=4, head_dim=64, d_ff=2048,
                           vocab_size=32000)
    else:
        n_heads = max(args.d_model // 64, 2)
        cfg = base.variant(
            n_layers=args.layers, d_model=args.d_model, n_heads=n_heads,
            n_kv_heads=max(n_heads // 2, 1), head_dim=64,
            d_ff=args.d_model * 3, vocab_size=2048,
            n_image_patches=0, sliding_window=None, long_context_window=None)
    n_params = cfg.param_count()
    print(f"training {args.arch} variant: {n_params/1e6:.1f}M params, "
          f"{args.steps} steps, batch {args.batch}x{args.seq}")

    data = packed_batches(batch=args.batch, seq_len=args.seq, seed=0,
                          vocab_limit=cfg.vocab_size)
    data = Prefetcher({k: jnp.asarray(v) for k, v in b.items()}
                      for b in data)
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, warmup_steps=20, total_steps=args.steps),
        log_every=20, ckpt_every=max(args.steps // 2, 1),
        ckpt_dir=args.ckpt_dir)
    params, opt, history = train(cfg, iter(data), steps=args.steps, tcfg=tcfg)
    CKPT.save_checkpoint(os.path.join(args.ckpt_dir, f"ckpt_{args.steps}"),
                         {"params": params, "opt": opt}, step=args.steps)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")
    print(f"checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
