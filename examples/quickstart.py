"""Quickstart: decompose a query into a DAG, train the utility router,
and route subtasks between edge and cloud with the adaptive threshold.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.hybridflow import Pipeline
from repro.core.planner import plan_to_xml
from repro.core.profiler import train_default_router
from repro.core.utility import UnifiedMetric
from repro.data.tasks import gen_benchmark


def main():
    print("== 1. Offline: profile subtasks and warm-start the router ==")
    router, info = train_default_router(n_queries=150, epochs=80)
    print(f"   {info['n_samples']} profiled subtasks, final MSE "
          f"{info['final_mse']:.4f}\n")

    pipe = Pipeline()
    query = gen_benchmark("gpqa", 3)[2]
    print(f"== 2. Decompose: {query.text} ==")
    dag, status = pipe.plan(query)
    print(f"   plan status: {status}; XML:\n{plan_to_xml(dag)}\n")

    print("== 3. Route and execute (dependency-triggered, budget-aware) ==")
    out = pipe.hybridflow([query], router)
    res = out.results[0]
    for sid, r in sorted(res.results.items()):
        where = "CLOUD" if r.routed_cloud else "edge "
        print(f"   t{sid} -> {where}  correct={r.correct}  "
              f"lat={r.latency:.2f}s  cost=${r.api_cost:.4f}")
    print(f"   threshold trace: "
          f"{[round(t, 3) for t in res.tau_trace]}")
    print(f"   final: correct={res.final_correct}  makespan={res.latency:.2f}s"
          f"  C_API=${res.api_cost:.4f}\n")

    print("== 4. Compare against edge-only / cloud-only on 100 queries ==")
    qs = gen_benchmark("gpqa", 100)
    edge = pipe.cot(qs, "edge")
    cloud = pipe.cot(qs, "cloud")
    hf = pipe.hybridflow(qs, router)
    for name, m in (("edge-only", edge), ("cloud-only", cloud),
                    ("hybridflow", hf)):
        um = UnifiedMetric(m.accuracy, m.latency, m.api_cost)
        c = um.normalized_cost(edge_latency=edge.latency)
        u = um.utility(edge.accuracy, edge.latency) if c > 0.02 else float("nan")
        print(f"   {name:12s} acc={100*m.accuracy:5.1f}%  "
              f"lat={m.latency:5.2f}s  api=${m.api_cost:.4f}  u={u:.3f}")


if __name__ == "__main__":
    main()
