"""Budget-adaptation demo: sweep the per-query API budget K_max and watch
HybridFlow trace the accuracy-cost frontier (the knapsack dual in action),
with the DP oracle as the upper bound (paper App. B).

    PYTHONPATH=src python examples/budget_sweep.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.hybridflow import Pipeline
from repro.core.profiler import train_default_router
from repro.core.utility import knapsack_oracle, normalized_cost
from repro.data.tasks import gen_benchmark


def oracle_accuracy(pipe, qs, budget):
    """Knapsack-optimal allocation with TRUE (Δq, c) — the upper bound."""
    correct = []
    for q in qs:
        dq, c = [], []
        for st in q.subtasks:
            d, dl, dk = pipe.wm.deltas(q, st)
            dq.append(max(d, 0.0))
            c.append(normalized_cost(dl, dk))
        r, _ = knapsack_oracle(dq, c, budget)
        routing = {st.sid: int(r[i]) for i, st in enumerate(q.subtasks)}
        correct.append(pipe.wm.final_correct(q, routing))
    return float(np.mean(correct))


def main():
    router, _ = train_default_router(n_queries=200, epochs=100)
    pipe = Pipeline()
    qs = gen_benchmark("gpqa", 120)
    print(f"{'K_max':>8s} {'offload%':>9s} {'acc%':>6s} {'api$':>8s} "
          f"{'oracle-acc%':>11s}")
    for kmax in (0.005, 0.01, 0.02, 0.04, 0.08):
        m = pipe.hybridflow(qs, router, k_max=kmax)
        # equivalent normalized budget for the oracle: kmax on the Eq.24 scale
        budget = 0.5 * kmax / 0.02 * 4.5  # ~per-query, 4.5 subtasks
        oa = oracle_accuracy(pipe, qs, budget)
        print(f"{kmax:8.3f} {100*m.offload_rate:9.1f} {100*m.accuracy:6.1f} "
              f"{m.api_cost:8.4f} {100*oa:11.1f}")
    print("\nHigher K_max -> more offloading -> higher accuracy & cost;")
    print("the DP oracle (exact Δq,c) bounds what the learned router can do.")


if __name__ == "__main__":
    main()
