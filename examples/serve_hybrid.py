"""Serve a HybridFlow deployment with REAL JAX executor models.

Two serving engines (a small 'edge' model and a larger 'cloud' model, both
reduced variants of assigned architectures) execute subtasks scheduled by
the dependency-aware router; latency is measured wall-clock from actual
model decode steps through the batched engine.

    PYTHONPATH=src python examples/serve_hybrid.py --queries 8
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, PAPER_EDGE_ARCH, PAPER_CLOUD_ARCH
from repro.core.hybridflow import HybridFlowPolicy
from repro.core.profiler import train_default_router
from repro.core.scheduler import run_query
from repro.data.tasks import gen_benchmark, WorldModel
from repro.models import model as M
from repro.serving.engine import ServingEngine, JAXExecutor


def build_engine(arch: str, scale: int, seed: int) -> ServingEngine:
    cfg = get_config(arch).reduced()
    if scale > 1:  # "cloud": wider/deeper variant
        cfg = cfg.variant(d_model=cfg.d_model * 2 // 128 * 128 or 256,
                          n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(seed), dtype=jnp.float32)
    return ServingEngine(cfg, params, batch_slots=2, max_len=192)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--edge-arch", default=PAPER_EDGE_ARCH)
    ap.add_argument("--cloud-arch", default=PAPER_CLOUD_ARCH)
    args = ap.parse_args()

    print(f"edge executor: {args.edge_arch} (reduced); "
          f"cloud executor: {args.cloud_arch} (reduced x2)")
    wm = WorldModel()
    edge_engine = build_engine(args.edge_arch, 1, 0)
    cloud_engine = build_engine(args.cloud_arch, 2, 1)
    edge = JAXExecutor(edge_engine, wm, cloud=False, concurrency=1)
    cloud = JAXExecutor(cloud_engine, wm, cloud=True, concurrency=4,
                        price_out=3.2e-5)

    router, _ = train_default_router(n_queries=100, epochs=60)
    policy = HybridFlowPolicy(router, wm=wm)

    from repro.core.planner import SyntheticPlanner
    planner = SyntheticPlanner()
    qs = gen_benchmark("gpqa", args.queries)
    t0 = time.time()
    n_correct = 0
    total_cost = 0.0
    for q in qs:
        dag, status = planner.plan(q)
        res = run_query(q, dag, policy, edge, cloud, plan_status=status)
        n_correct += res.final_correct
        total_cost += res.api_cost
        routed = "".join("C" if res.offload[s] else "e"
                         for s in sorted(res.offload))
        print(f"  {q.qid:10s} plan={status:8s} route={routed:8s} "
              f"correct={res.final_correct} wall={res.latency:.2f}s")
    wall = time.time() - t0
    print(f"\n{args.queries} queries in {wall:.1f}s; accuracy "
          f"{n_correct}/{args.queries}; API cost ${total_cost:.4f}")
    print(f"edge engine: {edge_engine.stats}")
    print(f"cloud engine: {cloud_engine.stats}")


if __name__ == "__main__":
    main()
