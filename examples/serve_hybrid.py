"""Serve a HybridFlow deployment with REAL JAX executor models, many
queries in flight at once.

Quickstart
----------
Two serving engines (a small 'edge' model and a larger 'cloud' model,
both reduced variants of assigned architectures) execute subtasks
scheduled by the dependency-aware router. The multi-query runtime admits
every query up front; dispatch goes through the fleet scheduler's *async
pump loop*: every routed subtask is ``submit``-ed into its engine's
queue, the loop keeps stepping both engines while routing continues, and
co-scheduled subtasks from different queries decode in the same
micro-batches. Prefill is batched and chunked — all newly admitted slots
prefill in one padded call that writes KV lines straight into the shared
slot pool, and prompts longer than ``prefill_chunk`` advance one chunk
per step so they never stall co-resident decodes. Sampling happens on
device inside the jitted step (one host transfer of token ids per step).

    # pumped fleet serving (default: 8 queries in flight)
    PYTHONPATH=src python examples/serve_hybrid.py --queries 8

    # pre-pump synchronous dispatch (engines never co-batch queries)
    PYTHONPATH=src python examples/serve_hybrid.py --queries 8 --no-pump

    # compare against the seed's one-query-at-a-time loop
    PYTHONPATH=src python examples/serve_hybrid.py --queries 8 --sequential

    # cap fleet-wide API spend; exhaustion forces edge execution
    PYTHONPATH=src python examples/serve_hybrid.py --global-k-max 0.01

    # shard the cloud engine across 2 pool replicas (shared params,
    # independent KV slot pools; cloud concurrency = replicas x slots)
    PYTHONPATH=src python examples/serve_hybrid.py --cloud-replicas 2

The printed report includes fleet throughput, p50/p99 per-query
makespan, accuracy and API cost, plus the engines' counters —
``slot_reuses`` > 0 shows requests recycling the bounded cache pool,
``peak_active`` >= 2 shows genuine cross-query co-residency, and
``prefill_batch_max`` >= 2 shows the prefill planner batching admitted
requests into single calls.

Programmatic use mirrors the CLI::

    from repro.serving import ServingConfig, ServingRuntime
    rt = ServingRuntime(edge, cloud, policy, planner=planner,
                        config=ServingConfig(max_inflight=8))
    report = rt.serve(queries)   # or rt.serve(queries, mode="sequential")
    print(report.summary())
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.configs import get_config, PAPER_EDGE_ARCH, PAPER_CLOUD_ARCH
from repro.core.hybridflow import HybridFlowPolicy
from repro.core.planner import SyntheticPlanner
from repro.core.profiler import train_default_router
from repro.data.tasks import gen_benchmark, WorldModel
from repro.models import model as M
from repro.serving import ServingConfig, ServingRuntime
from repro.serving.engine import ServingEngine, JAXExecutor


def build_engine(arch: str, scale: int, seed: int,
                 batch_slots: int = 2,
                 prefill_chunk: int = 64) -> ServingEngine:
    cfg = get_config(arch).reduced()
    if scale > 1:  # "cloud": wider/deeper variant
        cfg = cfg.variant(d_model=cfg.d_model * 2 // 128 * 128 or 256,
                          n_layers=2)
    params = M.init_params(cfg, jax.random.PRNGKey(seed), dtype=jnp.float32)
    return ServingEngine(cfg, params, batch_slots=batch_slots, max_len=192,
                         prefill_chunk=prefill_chunk)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=6)
    ap.add_argument("--edge-arch", default=PAPER_EDGE_ARCH)
    ap.add_argument("--cloud-arch", default=PAPER_CLOUD_ARCH)
    ap.add_argument("--max-inflight", type=int, default=8)
    ap.add_argument("--cloud-replicas", type=int, default=1,
                    help="shard the cloud engine across R pool replicas")
    ap.add_argument("--global-k-max", type=float, default=None)
    ap.add_argument("--sequential", action="store_true")
    ap.add_argument("--no-pump", action="store_true",
                    help="synchronous per-subtask dispatch (pre-pump "
                         "baseline)")
    args = ap.parse_args()

    print(f"edge executor: {args.edge_arch} (reduced); "
          f"cloud executor: {args.cloud_arch} (reduced x2)")
    wm = WorldModel()
    edge_engine = build_engine(args.edge_arch, 1, 0, batch_slots=2)
    cloud_engine = build_engine(args.cloud_arch, 2, 1, batch_slots=4)
    edge = JAXExecutor(edge_engine, wm, cloud=False, concurrency=1)
    # concurrency derives from capacity; --cloud-replicas scales this
    # executor out to an EnginePool inside the runtime
    cloud = JAXExecutor(cloud_engine, wm, cloud=True, price_out=3.2e-5)

    router, _ = train_default_router(n_queries=100, epochs=60)
    policy = HybridFlowPolicy(router, wm=wm)
    config = ServingConfig(max_inflight=args.max_inflight,
                           global_k_max=args.global_k_max,
                           pump=False if args.no_pump else None,
                           replicas=args.cloud_replicas)
    runtime = ServingRuntime(edge, cloud, policy, planner=SyntheticPlanner(),
                             config=config)

    qs = gen_benchmark("gpqa", args.queries)
    t0 = time.time()
    report = runtime.serve(
        qs, mode="sequential" if args.sequential else "fleet")
    for q, res in zip(qs, report.results):
        routed = "".join("C" if res.offload[s] else "e"
                         for s in sorted(res.offload))
        print(f"  {q.qid:10s} plan={res.plan_status:8s} route={routed:8s} "
              f"correct={res.final_correct} wall={res.latency:.2f}s")
    mode = "sequential" if args.sequential else \
        (f"{'sync' if args.no_pump else 'pumped'}"
         f"(max_inflight={args.max_inflight})")
    print(f"\n[{mode}] {report.summary()} | real {time.time()-t0:.1f}s")
    print(f"edge engine: {edge_engine.stats}")
    cloud_eng = runtime.cloud.engine     # EnginePool when replicas > 1
    print(f"cloud engine: {cloud_eng.stats}")
    if hasattr(cloud_eng, "occupancy"):
        for o in cloud_eng.occupancy():
            print(f"  cloud replica {o['replica']}: "
                  f"requests={o['requests']} "
                  f"peak_active={o['peak_active']}/{o['slots']}")


if __name__ == "__main__":
    main()
