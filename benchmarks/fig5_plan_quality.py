"""Paper Fig. 5 (App. D): intrinsic plan-quality across planner variants —
five dimensions per planner, comparing a clean planner, the default noisy
planner (Llama3.2-3B proxy), a heavily-corrupted planner (weak base model
proxy), and the chain-only planner."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.plan_quality import mean_quality
from repro.core.planner import SyntheticPlanner, CorruptionRates
from benchmarks.table7_planner import ChainPlanner


def run(n_queries=None):
    qs = C.queries("gpqa", n_queries or 200)
    planners = {
        "oracle-planner": SyntheticPlanner(CorruptionRates(0, 0, 0, 0, 0, 0, 0)),
        "default-planner": SyntheticPlanner(),
        "weak-planner": SyntheticPlanner(CorruptionRates(
            extra_cycle=0.2, drop_edge=0.25, double_generate=0.15,
            bad_requires=0.2, oversize=0.1, garble_xml=0.1,
            severe_garble=0.25)),
        "chain-planner": ChainPlanner(),
    }
    rows = []
    for name, pl in planners.items():
        q = mean_quality(qs, pl)
        rows.append([name, q["soundness"], q["dependency"], q["clarity"],
                     q["attributes"], q["efficiency"], q["overall"]])
    return ["planner", "soundness", "dependency_f1", "clarity",
            "attributes", "efficiency", "overall"], rows


def main():
    header, rows = run()
    C.print_csv("fig5_plan_quality", header, rows)


if __name__ == "__main__":
    main()
