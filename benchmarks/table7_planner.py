"""Paper Table 7: planner parallelization — avg steps, compression ratio
R_comp = (n - L_crit)/n, and end-to-end C_time / accuracy with the DAG
planner vs the chain fallback (SFT-vs-base proxy: our synthetic planner
vs a chain-only planner)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C
from repro.core.dag import compression_ratio, chain_fallback
from repro.core.planner import SyntheticPlanner


class ChainPlanner(SyntheticPlanner):
    """Planner without dependency structure (sequential-only baseline)."""

    def plan(self, query):
        dag, _ = super().plan(query)
        return chain_fallback(dag), "fallback"


def run(n_queries=None):
    router = C.shared_router()
    qs = C.queries("gpqa", n_queries)
    rows = []
    for name, planner in (("chain-planner", ChainPlanner()),
                          ("dag-planner", SyntheticPlanner())):
        pipe = C.shared_pipeline(0)
        old = pipe.planner
        pipe.planner = planner
        try:
            m = pipe.hybridflow(qs, router)
            rcs, steps = [], []
            for q in qs:
                dag, _ = planner.plan(q)
                rcs.append(compression_ratio(dag))
                steps.append(dag.n)
            rows.append([name, float(np.mean(steps)),
                         100 * float(np.mean(rcs)), m.latency,
                         100 * m.accuracy])
        finally:
            pipe.planner = old
    return ["planner", "avg_steps", "r_comp_pct", "c_time_s", "acc_pct"], rows


def main():
    header, rows = run()
    C.print_csv("table7_planner", header, rows)


if __name__ == "__main__":
    main()
