"""Benchmark driver — one section per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only tableX,...] [--fast]``
prints CSV sections and writes them to benchmarks/artifacts/results/.
Roofline reads the dry-run JSONs if present.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C


def all_benchmarks():
    from benchmarks import (table1_accuracy, table2_efficiency,
                            table3_ablation, table5_dag_validity,
                            table6_threshold_sweep, table7_planner,
                            table8_pair_swap, fig3_offload,
                            fig5_plan_quality, exposure_bench,
                            kernels_bench, roofline, serve_throughput)
    return {
        "table1": table1_accuracy,
        "table2": table2_efficiency,
        "table3": table3_ablation,
        "table5": table5_dag_validity,
        "table6": table6_threshold_sweep,
        "table7": table7_planner,
        "table8": table8_pair_swap,
        "fig3": fig3_offload,
        "fig5": fig5_plan_quality,
        "exposure": exposure_bench,
        "kernels": kernels_bench,
        "roofline": roofline,
        "serve": serve_throughput,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--fast", action="store_true",
                    help="fewer queries/seeds for smoke runs")
    args = ap.parse_args()
    if args.fast:
        C.N_QUERIES = 60
        C.N_SEEDS = 1

    benches = all_benchmarks()
    names = args.only.split(",") if args.only else list(benches)
    outdir = os.path.join(os.path.dirname(__file__), "artifacts", "results")
    os.makedirs(outdir, exist_ok=True)

    failures = 0
    for name in names:
        mod = benches[name]
        t0 = time.time()
        try:
            header, rows = mod.run()
        except Exception:
            print(f"\n# {name} FAILED\n{traceback.format_exc()[-1500:]}")
            failures += 1
            continue
        C.print_csv(f"{name} ({time.time() - t0:.1f}s)", header, rows)
        with open(os.path.join(outdir, f"{name}.csv"), "w") as f:
            f.write(",".join(header) + "\n")
            for r in rows:
                f.write(",".join(C._fmt(x) for x in r) + "\n")
    if failures:
        raise SystemExit(f"{failures} benchmark(s) failed")


if __name__ == "__main__":
    main()
