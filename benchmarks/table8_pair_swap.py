"""Paper Table 8 (App. D.2): model-pair swap — Qwen2.5-7B-class edge +
DeepSeek-V3-class cloud profiles, all pipeline logic unchanged."""
from __future__ import annotations

from benchmarks import common as C


def run(n_queries=None):
    router = C.shared_router()
    qs = C.queries("gpqa", n_queries)
    arms = {
        "all-edge-cot": lambda p: p.cot(qs, "edge"),
        "all-cloud-cot": lambda p: p.cot(qs, "cloud"),
        "hybridllm": lambda p: p.hybridllm(qs, router),
        "dot": lambda p: p.dot(qs, router),
        "hybridflow": lambda p: p.hybridflow(qs, router),
    }
    rows = []
    for name, fn in arms.items():
        stats = C.seeded_runs(
            lambda s, fn=fn: fn(C.shared_pipeline(s, swap=True)))
        rows.append([name, 100 * stats["acc"], 1000 * stats["api"],
                     stats["lat"]])
    return ["method", "acc_pct", "api_cost_musd", "latency_s"], rows


def main():
    header, rows = run()
    C.print_csv("table8_pair_swap", header, rows)


if __name__ == "__main__":
    main()
