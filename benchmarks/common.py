"""Shared benchmark infrastructure: trained router, pipelines, metrics."""
from __future__ import annotations

import functools
import sys
import os
from typing import Dict, Optional, Sequence

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.hybridflow import Pipeline
from repro.core.profiler import train_default_router
from repro.core.router import Router
from repro.core.utility import UnifiedMetric
from repro.data.tasks import (WorldModel, gen_benchmark, SWAP_EDGE_PROFILE,
                              SWAP_CLOUD_PROFILE)

BENCHES = ("gpqa", "mmlu_pro", "aime24", "livebench_reasoning")
N_QUERIES = int(os.environ.get("REPRO_BENCH_QUERIES", "150"))
N_SEEDS = int(os.environ.get("REPRO_BENCH_SEEDS", "3"))


@functools.lru_cache(maxsize=4)
def shared_router(seed: int = 0) -> Router:
    router, info = train_default_router(n_queries=300, epochs=120, seed=seed)
    return router


@functools.lru_cache(maxsize=8)
def shared_pipeline(seed: int = 0, swap: bool = False) -> Pipeline:
    if swap:
        wm = WorldModel(SWAP_EDGE_PROFILE, SWAP_CLOUD_PROFILE, seed=seed)
    else:
        wm = WorldModel(seed=seed)
    return Pipeline(wm=wm)


def queries(bench: str, n: Optional[int] = None):
    return gen_benchmark(bench, n or N_QUERIES)


def seeded_runs(fn, n_seeds: int = None) -> Dict[str, float]:
    """Run fn(seed) -> MethodOutput over seeds; mean/std of each metric."""
    n_seeds = n_seeds or N_SEEDS
    accs, lats, costs, offs = [], [], [], []
    for s in range(n_seeds):
        m = fn(s)
        accs.append(m.accuracy)
        lats.append(m.latency)
        costs.append(m.api_cost)
        offs.append(m.offload_rate)
    return {
        "acc": float(np.mean(accs)), "acc_std": float(np.std(accs)),
        "lat": float(np.mean(lats)), "lat_std": float(np.std(lats)),
        "api": float(np.mean(costs)), "api_std": float(np.std(costs)),
        "offload": float(np.mean(offs)),
    }


def unified(acc, lat, api, *, edge_acc, edge_lat, min_c: float = 0.02):
    um = UnifiedMetric(acc, lat, api)
    c = um.normalized_cost(edge_latency=edge_lat)
    u = um.utility(edge_acc, edge_lat) if c >= min_c else float("nan")
    return c, u


def print_csv(title: str, header: Sequence[str], rows: Sequence[Sequence]):
    print(f"\n# {title}")
    print(",".join(header))
    for r in rows:
        print(",".join(_fmt(x) for x in r))


def _fmt(x):
    if isinstance(x, float):
        return f"{x:.4f}"
    return str(x)
