"""Paper Fig. 3: edge/cloud execution counts per subtask position + the
average adaptive threshold at each position (GPQA)."""
from __future__ import annotations

import numpy as np

from benchmarks import common as C


def run(n_queries=None):
    router = C.shared_router()
    pipe = C.shared_pipeline(0)
    qs = C.queries("gpqa", n_queries)
    m = pipe.hybridflow(qs, router)
    max_pos = 7
    edge_cnt = np.zeros(max_pos, int)
    cloud_cnt = np.zeros(max_pos, int)
    tau_sum = np.zeros(max_pos)
    tau_n = np.zeros(max_pos, int)
    for r in m.results:
        # offload decisions in routing order; tau_trace aligned
        for pos, (sid, choice) in enumerate(r.offload.items()):
            if pos >= max_pos:
                break
            if choice:
                cloud_cnt[pos] += 1
            else:
                edge_cnt[pos] += 1
        for pos, tau in enumerate(r.tau_trace[:max_pos]):
            tau_sum[pos] += tau
            tau_n[pos] += 1
    rows = []
    for pos in range(max_pos):
        n = tau_n[pos]
        rows.append([pos, int(edge_cnt[pos]), int(cloud_cnt[pos]),
                     tau_sum[pos] / n if n else float("nan")])
    return ["position", "edge_count", "cloud_count", "avg_threshold"], rows


def main():
    header, rows = run()
    C.print_csv("fig3_offload_distribution", header, rows)


if __name__ == "__main__":
    main()
