"""Paper Table 6 / Fig. 4: fixed offload threshold τ0 sweep on GPQA
(sequential execution, as in the paper's sweep)."""
from __future__ import annotations


from benchmarks import common as C


def run(n_queries=None):
    router = C.shared_router()
    qs = C.queries("gpqa", n_queries)
    edge = C.seeded_runs(lambda s: C.shared_pipeline(s).cot(qs, "edge"))
    rows = []
    for tau0 in (0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 1.0):
        stats = C.seeded_runs(
            lambda s, t=tau0: C.shared_pipeline(s).fixed(qs, router, t))
        c, u = C.unified(stats["acc"], stats["lat"], stats["api"],
                         edge_acc=edge["acc"], edge_lat=edge["lat"])
        rows.append([tau0, 100 * stats["offload"], 100 * stats["acc"],
                     stats["lat"], stats["api"], c, u])
    # adaptive reference row (the paper's conclusion: beats any fixed τ0)
    hf = C.seeded_runs(
        lambda s: C.shared_pipeline(s).hybridflow(qs, router))
    c, u = C.unified(hf["acc"], hf["lat"], hf["api"],
                     edge_acc=edge["acc"], edge_lat=edge["lat"])
    rows.append(["adaptive", 100 * hf["offload"], 100 * hf["acc"],
                 hf["lat"], hf["api"], c, u])
    return ["tau0", "offload_pct", "acc_pct", "latency_s", "api_usd",
            "norm_cost_c", "utility_u"], rows


def main():
    header, rows = run()
    C.print_csv("table6_threshold_sweep", header, rows)


if __name__ == "__main__":
    main()
