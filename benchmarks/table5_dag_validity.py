"""Paper Table 5: planner DAG validity / repair / fallback statistics."""
from __future__ import annotations

from collections import Counter

import numpy as np

from benchmarks import common as C
from repro.core.planner import SyntheticPlanner
from repro.core.dag import validate


def run(n_queries=None):
    pl = SyntheticPlanner()
    rows = []
    for bench in ("gpqa", "livebench_reasoning"):
        qs = C.queries(bench, n_queries or 400)
        stats = Counter()
        nodes = []
        for q in qs:
            dag, status = pl.plan(q)
            assert validate(dag).ok
            stats[status] += 1
            nodes.append(dag.n)
        tot = sum(stats.values())
        rows.append([bench, 100 * stats["valid"] / tot,
                     100 * stats["repaired"] / tot,
                     100 * stats["fallback"] / tot,
                     float(np.mean(nodes))])
    return ["benchmark", "valid_pct", "repaired_pct", "fallback_pct",
            "avg_nodes"], rows


def main():
    header, rows = run()
    C.print_csv("table5_dag_validity", header, rows)


if __name__ == "__main__":
    main()
