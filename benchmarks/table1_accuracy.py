"""Paper Table 1: accuracy (% mean±std) of every method across the four
reasoning benchmarks."""
from __future__ import annotations

from benchmarks import common as C


def methods(pipe, router):
    return {
        "direct-edge": lambda qs: pipe.direct(qs, "edge"),
        "direct-cloud": lambda qs: pipe.direct(qs, "cloud"),
        "cot-edge": lambda qs: pipe.cot(qs, "edge"),
        "cot-cloud": lambda qs: pipe.cot(qs, "cloud"),
        "sot-edge": lambda qs: pipe.sot(qs, "edge"),
        "sot-cloud": lambda qs: pipe.sot(qs, "cloud"),
        "pasta-edge": lambda qs: pipe.pasta(qs, "edge"),
        "pasta-cloud": lambda qs: pipe.pasta(qs, "cloud"),
        "hybridllm": lambda qs: pipe.hybridllm(qs, router),
        "dot": lambda qs: pipe.dot(qs, router),
        "hybridflow": lambda qs: pipe.hybridflow(qs, router),
    }


def run_method(name: str, qs, seed: int, swap: bool = False):
    pipe = C.shared_pipeline(seed, swap)
    return methods(pipe, C.shared_router())[name](qs)


def run(n_queries=None):
    names = list(methods(C.shared_pipeline(0), C.shared_router()))
    rows, per_bench = [], {}
    for bench in C.BENCHES:
        qs = C.queries(bench, n_queries)
        for name in names:
            stats = C.seeded_runs(
                lambda s, name=name, qs=qs: run_method(name, qs, s))
            per_bench.setdefault(name, []).append(stats["acc"])
            rows.append([name, bench, 100 * stats["acc"],
                         100 * stats["acc_std"]])
    for name, accs in per_bench.items():
        rows.append([name, "AVG", 100 * sum(accs) / len(accs), 0.0])
    return ["method", "benchmark", "acc_pct", "acc_std"], rows


def main():
    header, rows = run()
    C.print_csv("table1_accuracy", header, rows)


if __name__ == "__main__":
    main()
