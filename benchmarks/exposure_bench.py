"""Paper App. D.1: privacy exposure proxy E_cloud / Ē_cloud per method."""
from __future__ import annotations

from benchmarks import common as C
from repro.core.exposure import mean_exposure


def run(n_queries=None):
    router = C.shared_router()
    pipe = C.shared_pipeline(0)
    qs = C.queries("gpqa", n_queries)
    arms = {
        "edge-only": pipe.cot(qs, "edge"),
        "cloud-only": pipe.cot(qs, "cloud"),
        "dot": pipe.dot(qs, router),
        "hybridflow": pipe.hybridflow(qs, router),
    }
    rows = []
    for name, m in arms.items():
        e, nbar = mean_exposure(m.results)
        rows.append([name, e, nbar, 100 * m.accuracy])
    return ["method", "e_cloud_tokens", "e_cloud_normalized", "acc_pct"], rows


def main():
    header, rows = run()
    C.print_csv("exposure_proxy", header, rows)


if __name__ == "__main__":
    main()
