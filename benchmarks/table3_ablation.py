"""Paper Table 3: routing-strategy ablation on GPQA — offload rate,
accuracy, latency, API cost, normalized cost c, unified utility u."""
from __future__ import annotations


from benchmarks import common as C


def run(n_queries=None):
    router = C.shared_router()
    rows = []
    arms = {
        "edge": lambda p, qs: p.cot(qs, "edge"),
        "cloud": lambda p, qs: p.cot(qs, "cloud"),
        "random": lambda p, qs: p.random(qs, p=0.42),
        "fixed-0.5": lambda p, qs: p.fixed(qs, router, 0.5),
        "hybridflow-chain": lambda p, qs: p.hybridflow(qs, router, chain=True),
        "hybridflow": lambda p, qs: p.hybridflow(qs, router),
        "hybridflow+bandit": lambda p, qs: p.hybridflow(qs, router,
                                                        calibrate=True),
        # beyond-paper: per-query DP allocation on predicted utilities
        "knapsack-dp": lambda p, qs: p.knapsack(qs, router, budget=0.5),
    }
    qs = C.queries("gpqa", n_queries)
    edge_stats = C.seeded_runs(
        lambda s: arms["edge"](C.shared_pipeline(s), qs))
    for name, fn in arms.items():
        stats = C.seeded_runs(lambda s, fn=fn: fn(C.shared_pipeline(s), qs))
        c, u = C.unified(stats["acc"], stats["lat"], stats["api"],
                         edge_acc=edge_stats["acc"],
                         edge_lat=edge_stats["lat"])
        rows.append([name, 100 * stats["offload"], 100 * stats["acc"],
                     stats["lat"], stats["api"], c, u])
    return ["method", "offload_pct", "acc_pct", "latency_s", "api_usd",
            "norm_cost_c", "utility_u"], rows


def main():
    header, rows = run()
    C.print_csv("table3_ablation_gpqa", header, rows)


if __name__ == "__main__":
    main()
