"""Per-kernel timing: Pallas (interpret on CPU / compiled on TPU) vs the
XLA reference path. Prints name,us_per_call,derived CSV."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from benchmarks import common as C
from repro.kernels import ops, ref


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    key = jax.random.PRNGKey(0)
    on_tpu = jax.default_backend() == "tpu"
    # modest shapes: interpret mode on CPU is a correctness harness, not perf
    B, S, H, KV, hd = (4, 2048, 8, 2, 128) if on_tpu else (1, 256, 4, 2, 64)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    t_ref = _time(lambda: ref.attention_ref(q, k, v, causal=True))
    t_pal = _time(lambda: ops.flash_attention(q, k, v, causal=True))
    flops = 4 * B * H * S * S * hd / 2  # causal
    rows.append(["flash_attention_ref", t_ref, f"{flops/t_ref*1e-3:.1f}GF/s"])
    rows.append(["flash_attention_pallas", t_pal,
                 "interpret" if not on_tpu else f"{flops/t_pal*1e-3:.1f}GF/s"])

    M = 8192 if on_tpu else 1024
    kc = jax.random.normal(ks[1], (B, M, KV, hd), jnp.float32)
    vc = jax.random.normal(ks[2], (B, M, KV, hd), jnp.float32)
    qd = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    kvl = jnp.full((B,), M, jnp.int32)
    t_ref = _time(lambda: ref.decode_attention_ref(qd, kc, vc, kvl))
    t_pal = _time(lambda: ops.decode_attention(qd, kc, vc, kv_len=kvl))
    rows.append(["decode_attention_ref", t_ref, f"M={M}"])
    rows.append(["decode_attention_pallas", t_pal,
                 "interpret" if not on_tpu else f"M={M}"])

    # chunked GLA (Mamba2/mLSTM recurrence)
    from repro.models.linear_recurrence import chunked_gla as gla_xla
    B2, T, H2, D2 = (8, 4096, 8, 64) if on_tpu else (1, 128, 2, 16)
    ks = jax.random.split(key, 4)
    qg = jax.random.normal(ks[0], (B2, T, H2, D2), jnp.float32)
    kg = jax.random.normal(ks[1], (B2, T, H2, D2), jnp.float32)
    vg = jax.random.normal(ks[2], (B2, T, H2, D2), jnp.float32)
    lag = -jax.nn.softplus(jax.random.normal(ks[3], (B2, T, H2)))
    t_ref = _time(lambda: gla_xla(qg, kg, vg, lag, chunk=64)[0])
    t_pal = _time(lambda: ops.chunked_gla(qg, kg, vg, lag, chunk=64))
    rows.append(["chunked_gla_xla", t_ref, f"T={T}"])
    rows.append(["chunked_gla_pallas", t_pal,
                 "interpret" if not on_tpu else f"T={T}"])

    x = jax.random.normal(key, (4096 if on_tpu else 512, 1024), jnp.float32)
    s = jnp.ones((1024,))
    t_ref = _time(lambda: ref.rmsnorm_ref(x, s))
    t_pal = _time(lambda: ops.rmsnorm(x, s))
    gbs = 2 * x.size * 4 / 1e9
    rows.append(["rmsnorm_ref", t_ref, f"{gbs/(t_ref*1e-6):.1f}GB/s"])
    rows.append(["rmsnorm_pallas", t_pal,
                 "interpret" if not on_tpu else f"{gbs/(t_pal*1e-6):.1f}GB/s"])
    return ["name", "us_per_call", "derived"], rows


def main():
    header, rows = run()
    C.print_csv("kernels", header, rows)


if __name__ == "__main__":
    main()
