"""Paper Table 2: end-to-end latency C_time (s) and cloud API cost C_API
per method across benchmarks."""
from __future__ import annotations

from benchmarks import common as C
from benchmarks.table1_accuracy import methods, run_method


def run(n_queries=None):
    names = list(methods(C.shared_pipeline(0), C.shared_router()))
    rows = []
    agg = {}
    for bench in C.BENCHES:
        qs = C.queries(bench, n_queries)
        for name in names:
            stats = C.seeded_runs(
                lambda s, name=name, qs=qs: run_method(name, qs, s))
            agg.setdefault(name, []).append((stats["lat"], stats["api"]))
            rows.append([name, bench, stats["lat"], stats["lat_std"],
                         stats["api"]])
    for name, vals in agg.items():
        lat = sum(v[0] for v in vals) / len(vals)
        api = sum(v[1] for v in vals) / len(vals)
        rows.append([name, "AVG", lat, 0.0, api])
    return ["method", "benchmark", "c_time_s", "c_time_std", "c_api_usd"], rows


def main():
    header, rows = run()
    C.print_csv("table2_efficiency", header, rows)


if __name__ == "__main__":
    main()
