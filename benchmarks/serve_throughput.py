"""Fleet serving throughput: concurrent multi-query runtime vs the seed's
sequential one-query-at-a-time loop — analytic executors AND real JAX
engines.

Analytic section: for each in-flight level the same query stream runs
through the HybridFlow scheduler twice — once admitted all together
(bounded by ``max_inflight``), once back-to-back — reporting queries per
simulated second, p50/p99 per-query makespan, accuracy and API cost.

Real-engine section: the same fleet drives a ``JAXExecutor`` pair
(reduced-config models decoding for real) in two modes —

* ``real-sync``  — the pre-pump synchronous dispatch (``pump=False``):
  each subtask blocks in ``Executor.run`` and drains alone, so engine
  ``peak_active`` stays 1;
* ``real-pump``  — the async pump loop: co-scheduled subtasks decode in
  the same micro-batches via batched chunked prefill + batched decode.

The pump mode must beat the synchronous wall-clock by >= 1.3x (the
overlap is the whole point).

Pooled-cloud section: the same pumped fleet drives a cloud that is
either ONE serving engine (``real-cloud-single`` — the pre-pool shape,
capacity = its slot count) or an ``EnginePool`` of R replicas
(``real-cloud-poolR`` — capacity R x slots, least-loaded dispatch,
launch-all/commit-all pump passes). The pooled cloud must beat the
single engine on concurrent fleet wall-clock: extra replica slots drain
the cloud backlog sooner and each pass overlaps one replica's host
bookkeeping with another's device compute.

Degraded section: the pooled fleet runs clean (``real-faultfree``) and
under a seeded chaos plan (``real-degraded`` — 10% injected cloud submit
failures + one replica crash mid-run) with scheduler retry/degradation
armed, reporting the wall-clock overhead of absorbing the faults plus
the recovery counters (retries/timeouts/degraded/failovers/deaths).
Every query must still complete or the bench itself fails.

Open-loop section: a fixed seeded bursty arrival trace (base load, a
burst, a long zero-traffic gap, one post-gap arrival; wall-compressed)
replays with timed admission against an elastic 0→R cloud pool
(``real-openloop`` row — TTFT/queue-wait percentiles at the measured
offered RPS plus the autoscale event counters). The section hard-fails
unless every query completes, the pool scales to zero during the gap,
and the post-gap arrival pokes it back to warm. A separate analytic
``trace-gen`` row records the Poisson generator's measured mean RPS
against its target (``check_bench`` gates it within 5%).

Prefix-reuse section: a shared-system-prompt fleet shaped like the
executor's DAG prompts runs reuse-off then reuse-on through a direct
engine in deterministic subtask waves (``prefix-reuse-off`` /
``prefix-reuse-on`` rows). Bit-identity and exact token accounting
hard-fail inside the section; the rows' ``savings_pct`` / ``hit_rate``
metrics are pure functions of the prompt set, so they GATE in CI like
the analytic rows (reuse must keep skipping >= 40% of prefill work).
``--prefix-fleet N`` adds the heavy live-runtime twin (``real-prefix-*``
rows, nightly): the full pumped DAG fleet with scheduler prefix hints,
warn-only like every real-* row.

Two final sections microbench the serving attention ops themselves —
jnp reference vs Pallas kernel for ragged chunked prefill
(``prefill-ref`` / ``prefill-pallas`` rows) and for batched decode
(``decode-ref`` / ``decode-pallas`` rows). Results are also written as
machine-readable ``BENCH_serve.json`` rows ``{mode, qps, p50, p99,
prefill_tokens, peak_active, ...}`` for the cross-PR perf trajectory
(diffed against ``benchmarks/baseline_serve.json`` by
``benchmarks/check_bench.py`` in CI — the analytic and kernel-microbench
rows gate, the noisy real-engine wall-clock rows warn; the microbench
check also requires the Pallas row to beat its jnp reference row in the
same run).

``PYTHONPATH=src python -m benchmarks.serve_throughput [--queries N]
[--real-queries M] [--pool-queries K] [--json PATH]``
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C
from repro.core.hybridflow import HybridFlowPolicy
from repro.serving.runtime import ServingConfig, ServingRuntime

INFLIGHT_LEVELS = (2, 4, 8, 16)
MIN_REAL_SPEEDUP = 1.3


def _runtime(pipe, router, **kw):
    policy = HybridFlowPolicy(router, wm=pipe.wm)
    return ServingRuntime(pipe.edge, pipe.cloud, policy,
                          planner=pipe.planner, config=ServingConfig(**kw))


def run(n_queries=None, bench="gpqa"):
    n = n_queries or max(32, min(C.N_QUERIES, 64))
    pipe = C.shared_pipeline(0)
    router = C.shared_router()
    qs = C.queries(bench, n)

    rows = []
    seq = _runtime(pipe, router).serve(qs, mode="sequential")
    rows.append(["sequential", 1, n, seq.makespan, seq.qps,
                 seq.p50_latency, seq.p99_latency, seq.accuracy,
                 seq.api_cost])
    for m in INFLIGHT_LEVELS:
        rep = _runtime(pipe, router, max_inflight=m).serve(qs)
        rows.append([f"concurrent-{m}", m, n, rep.makespan, rep.qps,
                     rep.p50_latency, rep.p99_latency, rep.accuracy,
                     rep.api_cost])
        assert rep.stats["peak_inflight"] == min(m, n)
        if rep.qps <= seq.qps:
            print(f"WARNING: concurrent-{m} qps {rep.qps:.3f} did not beat "
                  f"sequential {seq.qps:.3f}")
    header = ["mode", "max_inflight", "queries", "makespan_s", "qps",
              "p50_s", "p99_s", "accuracy", "api_usd"]
    return header, rows


class _HashRoutePolicy:
    """Deterministic per-node routing (cloud unless sid % 3 == 0): the
    same decisions regardless of completion order, so sync vs pump run
    identical work and the wall-clock comparison is fair."""

    def decide(self, query, node, ctx):
        return int(node.sid % 3 != 0), {}

    def observe(self, query, node, r, result, ctx):
        pass


def run_real(n_queries=6, bench="gpqa", *, arch="qwen2-1.5b",
             max_inflight=8):
    """Real-JAX-engine fleet: synchronous dispatch vs the async pump."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel, gen_benchmark
    from repro.models import model as M
    from repro.serving.engine import JAXExecutor, ServingEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    wm = WorldModel()
    qs = gen_benchmark(bench, n_queries)

    def serve(pump: bool):
        edge_e = ServingEngine(cfg, params, batch_slots=2, max_len=160,
                               prefill_chunk=64)
        cloud_e = ServingEngine(cfg, params, batch_slots=4, max_len=160,
                                prefill_chunk=64)
        edge = JAXExecutor(edge_e, wm, cloud=False, concurrency=1)
        cloud = JAXExecutor(cloud_e, wm, cloud=True, concurrency=4,
                            price_out=3.2e-5)
        rt = ServingRuntime(edge, cloud, _HashRoutePolicy(),
                            planner=SyntheticPlanner(),
                            config=ServingConfig(max_inflight=max_inflight,
                                                 pump=pump))
        rep = rt.serve(qs)
        return rep, edge_e, cloud_e

    # warm-up BOTH modes: each produces its own prefill-group shapes
    # (pump: G>=2 per call; sync: G=1), so jit compiles must be paid
    # outside either timed window for a fair wall-clock comparison
    serve(True)
    serve(False)
    rows = []
    for mode, pump in (("real-sync", False), ("real-pump", True)):
        rep, edge_e, cloud_e = serve(pump)
        rows.append({
            "mode": mode,
            "queries": n_queries,
            "qps": rep.n / rep.wall_s if rep.wall_s > 0 else 0.0,
            "p50": rep.p50_latency,
            "p99": rep.p99_latency,
            "wall_s": rep.wall_s,
            "prefill_tokens": (edge_e.stats["prefill_tokens"]
                               + cloud_e.stats["prefill_tokens"]),
            "peak_active": max(edge_e.stats["peak_active"],
                               cloud_e.stats["peak_active"]),
            "prefill_batch_max": max(edge_e.stats["prefill_batch_max"],
                                     cloud_e.stats["prefill_batch_max"]),
        })
    speedup = rows[0]["wall_s"] / max(rows[1]["wall_s"], 1e-9)
    return rows, speedup


class _CloudBoundPolicy:
    """Every subtask to the cloud: the pooled section measures how cloud
    capacity scales, so the fleet must actually saturate the cloud pool
    (a mixed policy stalls on the 1-wide edge at every DAG root and the
    cloud never backs up)."""

    def decide(self, query, node, ctx):
        return 1, {}

    def observe(self, query, node, r, result, ctx):
        pass


def run_pool(n_queries=12, bench="gpqa", *, arch="qwen2-1.5b", replicas=2,
             slots=4, max_inflight=None):
    """Pooled-vs-single cloud under the pumped fleet: the same cloud
    engine shape as ``run_real`` (``slots`` KV slots) either alone (the
    pre-pool single cloud engine) or sharded across ``replicas``
    EnginePool replicas, drained by a cloud-bound query stream deep
    enough to keep every replica's slots leased."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel, gen_benchmark
    from repro.models import model as M
    from repro.serving.engine import JAXExecutor, ServingEngine
    from repro.serving.pool import EnginePool

    if replicas < 2:
        raise ValueError("run_pool compares a pooled cloud against the "
                         "single engine; needs replicas >= 2")
    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    wm = WorldModel()
    qs = gen_benchmark(bench, n_queries)
    max_inflight = max_inflight or n_queries

    def serve(R):
        edge_e = ServingEngine(cfg, params, batch_slots=2, max_len=160,
                               prefill_chunk=64)
        if R == 1:   # the existing single-engine cloud path, unpooled
            cloud_eng = ServingEngine(cfg, params, batch_slots=slots,
                                      max_len=160, prefill_chunk=64)
        else:
            cloud_eng = EnginePool.replicate(cfg, params, replicas=R,
                                             batch_slots=slots, max_len=160,
                                             prefill_chunk=64)
        edge = JAXExecutor(edge_e, wm, cloud=False, concurrency=1)
        cloud = JAXExecutor(cloud_eng, wm, cloud=True, price_out=3.2e-5)
        rt = ServingRuntime(edge, cloud, _CloudBoundPolicy(),
                            planner=SyntheticPlanner(),
                            config=ServingConfig(max_inflight=max_inflight,
                                                 pump=True))
        rep = rt.serve(qs)
        return rep, cloud_eng

    # one warm-up pays every jit compile for BOTH modes: _jit_steps is a
    # module-level cache keyed on (cfg, max_len, backend), which single
    # engine and pool replicas share (same shapes throughout)
    serve(replicas)
    rows = []
    for mode, R in (("real-cloud-single", 1),
                    (f"real-cloud-pool{replicas}", replicas)):
        rep, cloud_eng = serve(R)
        stats = cloud_eng.stats
        rows.append({
            "mode": mode,
            "queries": n_queries,
            "cloud_replicas": R,
            "cloud_capacity": cloud_eng.capacity,
            "qps": rep.n / rep.wall_s if rep.wall_s > 0 else 0.0,
            "p50": rep.p50_latency,
            "p99": rep.p99_latency,
            "wall_s": rep.wall_s,
            # per-replica high-water marks (their sum can overstate true
            # concurrency; the per-replica list is the honest evidence
            # that every replica's slots were leased)
            "replica_peak_active": [o["peak_active"]
                                    for o in cloud_eng.occupancy()]
            if hasattr(cloud_eng, "occupancy")
            else [stats["peak_active"]],
            "replica_requests": rep.stats.get("cloud_replica_requests",
                                              [stats["requests"]]),
        })
    speedup = rows[0]["wall_s"] / max(rows[1]["wall_s"], 1e-9)
    # every replica must have taken work (least-loaded dispatch spreads
    # a saturating fleet across the whole pool)
    assert all(n > 0 for n in rows[1]["replica_requests"]), \
        rows[1]["replica_requests"]
    return rows, speedup


def run_degraded(n_queries=12, bench="gpqa", *, arch="qwen2-1.5b",
                 replicas=2, slots=4):
    """Chaos-overhead section: the same pumped cloud-bound fleet runs
    clean (``real-faultfree``) and under a seeded fault plan
    (``real-degraded`` — 10% injected cloud submit failures plus one
    replica crash mid-run) with scheduler recovery armed. Every query
    must still complete; the row records the wall-clock overhead of
    riding out the faults (retry backoff + failover restarts + degraded
    edge decodes) next to the recovery counters that explain it."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.planner import SyntheticPlanner
    from repro.core.scheduler import RetryPolicy
    from repro.data.tasks import WorldModel, gen_benchmark
    from repro.models import model as M
    from repro.serving.engine import JAXExecutor, ServingEngine
    from repro.serving.faults import FaultPlan
    from repro.serving.pool import EnginePool

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    wm = WorldModel()
    qs = gen_benchmark(bench, n_queries)

    def serve(faults, retry):
        edge_e = ServingEngine(cfg, params, batch_slots=2, max_len=160,
                               prefill_chunk=64)
        pool = EnginePool.replicate(cfg, params, replicas=replicas,
                                    batch_slots=slots, max_len=160,
                                    prefill_chunk=64)
        edge = JAXExecutor(edge_e, wm, cloud=False, concurrency=1)
        cloud = JAXExecutor(pool, wm, cloud=True, price_out=3.2e-5)
        rt = ServingRuntime(edge, cloud, _CloudBoundPolicy(),
                            planner=SyntheticPlanner(),
                            config=ServingConfig(max_inflight=n_queries,
                                                 pump=True, faults=faults,
                                                 retry=retry))
        return rt.serve(qs)

    serve(None, None)                     # jit compiles outside both timings
    plan = FaultPlan(seed=0, submit_fail_rate=0.10, crash_replica=((1, 20),))
    retry = RetryPolicy(max_retries=2, timeout_s=30.0)
    rows = []
    for mode, faults, rp in (("real-faultfree", None, None),
                             ("real-degraded", plan, retry)):
        rep = serve(faults, rp)
        assert all(r is not None and len(r.results) == r.dag.n
                   for r in rep.results), f"{mode}: dropped a query"
        s = rep.stats
        rows.append({
            "mode": mode,
            "queries": n_queries,
            "cloud_replicas": replicas,
            "qps": rep.n / rep.wall_s if rep.wall_s > 0 else 0.0,
            "p50": rep.p50_latency,
            "p99": rep.p99_latency,
            "wall_s": rep.wall_s,
            "retries": s.get("retries", 0),
            "timeouts": s.get("timeouts", 0),
            "degraded": s.get("degraded", 0),
            "failovers": s.get("cloud_failovers", 0),
            "deaths": s.get("cloud_deaths", 0),
            "injected_submit_faults":
                s.get("injected", {}).get("submit_faults", 0),
        })
    rows[1]["overhead_pct"] = 100.0 * (
        rows[1]["wall_s"] / max(rows[0]["wall_s"], 1e-9) - 1.0)
    return rows, rows[1]["overhead_pct"]


def run_prefix(n_queries=6, *, arch="qwen2-1.5b", subtasks=4,
               max_new=8):
    """KV prefix-reuse fidelity + savings section (GATES in CI).

    A shared-system-prompt fleet shaped like the executor's DAG prompts
    (per-query context + per-subtask tail) runs twice through a direct
    engine — reuse off, then on — submitted in deterministic subtask
    waves (wave j = subtask j of every query; ``batch_slots=n_queries``
    keeps each query on its own slot, so the reuse pattern is a pure
    function of the prompts, not of timing). The section hard-fails
    unless greedy outputs are bit-identical and the token accounting is
    exact (``off prefill == on prefill + saved``); the emitted
    ``prefix-reuse-off`` / ``prefix-reuse-on`` rows carry the
    deterministic savings/hit-rate metrics ``check_bench`` gates on."""
    import time

    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.models import model as M
    from repro.serving.engine import ServingEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    # per-query shared context ~64 tokens (4 full PREFIX_BLOCKs); tails
    # ~25 tokens — reuse-on should skip >= 40% of prefill work
    ctx = {q: (f"[query {q:02d}] You are a careful assistant; reason "
               f"step by step about case {q:02d}. ")
           for q in range(n_queries)}
    waves = [[ctx[q] + f"subtask {j}: analyze aspect {j} of it"
              for q in range(n_queries)] for j in range(subtasks)]

    def serve(reuse: bool):
        eng = ServingEngine(cfg, params, batch_slots=n_queries, max_len=160,
                            prefill_chunk=32, prefix_reuse=reuse)
        outs = []
        t0 = time.perf_counter()
        for wave in waves:
            reqs = [eng.submit(p, max_new_tokens=max_new) for p in wave]
            eng.run_until_done()
            outs += [tuple(r.output_ids) for r in reqs]
        return outs, eng.stats, time.perf_counter() - t0

    serve(True)                                # pay jit compiles
    serve(False)
    off_out, off, off_s = serve(False)
    on_out, on, on_s = serve(True)
    assert on_out == off_out, \
        "prefix reuse broke bit-identity on the shared-prefix fleet"
    assert off["prefill_tokens"] == \
        on["prefill_tokens"] + on["prefill_tokens_saved"], \
        (off["prefill_tokens"], on["prefill_tokens"],
         on["prefill_tokens_saved"])
    n_req = n_queries * subtasks
    rows = []
    for mode, st, wall in (("prefix-reuse-off", off, off_s),
                           ("prefix-reuse-on", on, on_s)):
        saved = st["prefill_tokens_saved"]
        rows.append({
            "mode": mode,
            "queries": n_queries,
            "requests": n_req,
            "wall_s": wall,
            "tokens_out": st["tokens_out"],
            "prefill_tokens": st["prefill_tokens"],
            "prefill_tokens_saved": saved,
            "prefix_hits": st["prefix_hits"],
            "prefix_copies": st["prefix_copies"],
            # deterministic gating metrics: fraction of the no-reuse
            # prefill work skipped, and hits per reusable request
            "savings_pct": 100.0 * saved / max(off["prefill_tokens"], 1),
            "hit_rate": st["prefix_hits"] / max(n_req - n_queries, 1),
        })
    return rows, rows[1]["savings_pct"]


def run_prefix_fleet(n_queries=6, bench="gpqa", *, arch="qwen2-1.5b"):
    """Heavy live-runtime twin of :func:`run_prefix` (nightly): the full
    ServingRuntime DAG fleet — planner, pump loop, DAG prefix hints,
    pool-less executors — served with reuse on vs off. Answers must
    match exactly (greedy outputs depend only on the prompt, so the
    per-subtask answer map is dispatch-order-independent); the
    ``real-prefix-*`` rows record the wall-clock and prefill-token
    effect at fleet scale and WARN (never gate) like every real-* row."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel, gen_benchmark
    from repro.models import model as M
    from repro.serving.engine import JAXExecutor, ServingEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    wm = WorldModel()
    qs = gen_benchmark(bench, n_queries)

    def serve(reuse: bool):
        edge_e = ServingEngine(cfg, params, batch_slots=2, max_len=160,
                               prefill_chunk=64, prefix_reuse=reuse)
        cloud_e = ServingEngine(cfg, params, batch_slots=4, max_len=160,
                                prefill_chunk=64, prefix_reuse=reuse,
                                seed=1)
        edge = JAXExecutor(edge_e, wm, cloud=False, concurrency=1)
        cloud = JAXExecutor(cloud_e, wm, cloud=True, price_out=3.2e-5)
        rt = ServingRuntime(edge, cloud, _HashRoutePolicy(),
                            planner=SyntheticPlanner(),
                            config=ServingConfig(max_inflight=n_queries,
                                                 pump=True))
        rep = rt.serve(qs)
        answers = sorted((r.qid, s.sid, s.answer) for r in rep.results
                         for s in r.results.values())
        return rep, answers, edge_e, cloud_e

    serve(True)                                # pay jit compiles
    serve(False)
    rows = []
    maps = {}
    for mode, reuse in (("real-prefix-off", False), ("real-prefix-on", True)):
        rep, answers, edge_e, cloud_e = serve(reuse)
        maps[mode] = answers
        rows.append({
            "mode": mode,
            "queries": n_queries,
            "qps": rep.n / rep.wall_s if rep.wall_s > 0 else 0.0,
            "p50": rep.p50_latency,
            "p99": rep.p99_latency,
            "wall_s": rep.wall_s,
            "prefill_tokens": (edge_e.stats["prefill_tokens"]
                               + cloud_e.stats["prefill_tokens"]),
            "prefill_tokens_saved":
                (edge_e.stats["prefill_tokens_saved"]
                 + cloud_e.stats["prefill_tokens_saved"]),
            "prefix_hits": (edge_e.stats["prefix_hits"]
                            + cloud_e.stats["prefix_hits"]),
        })
    assert maps["real-prefix-on"] == maps["real-prefix-off"], \
        "prefix reuse changed a fleet answer (bit-identity broken)"
    saved = rows[1]["prefill_tokens_saved"]
    return rows, 100.0 * saved / max(rows[0]["prefill_tokens"], 1)


def run_trace_gen(*, rps=4.0, duration=600.0, seed=7):
    """Analytic trace-generator fidelity row (gates in CI): a seeded
    Poisson trace at a target RPS must measure within 5% of it over a
    long horizon, and the same seed must replay identically. Purely
    host-side arithmetic — deterministic on any machine."""
    from repro.serving.traffic import Trace

    tr = Trace.poisson(rps, duration, seed=seed)
    replay = Trace.poisson(rps, duration, seed=seed)
    assert tr.arrivals == replay.arrivals, \
        "trace generator is not deterministic under a fixed seed"
    return [{"mode": "trace-gen", "n": tr.n, "duration": duration,
             "seed": seed, "target_rps": rps, "measured_rps": tr.mean_rps,
             "rps_err_pct": 100.0 * abs(tr.mean_rps - rps) / rps}]


# the open-loop section's fixed trace: steady base load, a burst, a 20s
# zero-traffic gap, one post-gap arrival (seed 0 guarantees it) — then
# wall-compressed so the whole replay fits a bench run
_OPENLOOP_TRACE = dict(base_rps=0.12, duration=60.0, burst_rps=0.8,
                       burst_at=15.0, burst_s=5.0, gap_at=28.0, gap_s=20.0,
                       seed=0)


def run_openloop(bench="gpqa", *, arch="qwen2-1.5b", replicas=4,
                 scale=1 / 6):
    """Open-loop elastic serving: replay the fixed seeded bursty trace
    with timed admission against an elastic 0→``replicas`` cloud pool
    (scale-to-zero + modeled cold start armed). The row reports TTFT /
    queue-wait percentiles at the measured offered RPS plus the
    autoscale counters; the section itself hard-fails unless every query
    completes, the pool scales to zero during the gap, and the post-gap
    arrival pokes it back to warm."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel, gen_benchmark
    from repro.models import model as M
    from repro.serving import (AutoscalePolicy, ColdStartModel, EnginePool,
                               Trace)
    from repro.serving.engine import JAXExecutor, ServingEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    wm = WorldModel()

    def build(autoscale):
        edge_e = ServingEngine(cfg, params, batch_slots=2, max_len=160,
                               prefill_chunk=64)
        pool = EnginePool.replicate(cfg, params, replicas=replicas,
                                    batch_slots=2, max_len=160,
                                    prefill_chunk=64)
        edge = JAXExecutor(edge_e, wm, cloud=False, concurrency=1)
        cloud = JAXExecutor(pool, wm, cloud=True, price_out=3.2e-5)
        return ServingRuntime(edge, cloud, _CloudBoundPolicy(),
                              planner=SyntheticPlanner(),
                              config=ServingConfig(max_inflight=None,
                                                   pump=True,
                                                   autoscale=autoscale))

    build(None).serve(gen_benchmark(bench, 2))   # pay jit compiles

    trace = Trace.bursty(**_OPENLOOP_TRACE).scaled(scale)
    auto = AutoscalePolicy(min_replicas=0, scale_up_at=0.8,
                           scale_down_at=0.3, idle_to_zero_s=0.6,
                           cold_start=ColdStartModel(0.1, 0.15, 0.05))
    rep = build(auto).serve_trace(trace, gen_benchmark(bench, trace.n))
    a = rep.trace["autoscale"]
    assert all(r is not None and len(r.results) == r.dag.n
               for r in rep.results), "open loop dropped a query"
    assert a["scale_to_zero"] >= 1, \
        f"pool never scaled to zero during the gap: {a['events']}"
    assert a["pokes"] >= 2, \
        f"post-gap arrival never poked the pool warm: {a['events']}"
    return [{
        "mode": "real-openloop",
        "queries": trace.n,
        "trace": trace.label,
        "trace_seed": trace.seed,
        "offered_rps": rep.trace["offered_rps"],
        "qps": rep.n / rep.wall_s if rep.wall_s > 0 else 0.0,
        "p50": rep.p50_latency,
        "p99": rep.p99_latency,
        "ttft_p50": rep.p50_ttft,
        "ttft_p99": rep.p99_ttft,
        "queue_p99": rep.queue_wait_percentile(99.0),
        "wall_s": rep.wall_s,
        "cloud_replicas": replicas,
        "scale_ups": a["scale_ups"],
        "scale_downs": a["scale_downs"],
        "scale_to_zero": a["scale_to_zero"],
        "pokes": a["pokes"],
        "promotions": a["promotions"],
    }]


def run_prefill_microbench(*, G=4, S=64, W=256, H=4, KV=2, hd=64, iters=3):
    """Ref-vs-kernel ragged chunked-prefill attention microbench.

    Times the exact op ``serve_prefill_chunk`` dispatches per layer — the
    jnp reference twin vs the Pallas ragged kernel — on one engine-shaped
    workload (G chunk rows, ragged take/pos0, kv_width=W). On CPU the
    kernel runs in interpret mode, so treat these numbers as a
    plumbing/trajectory check; they become a real speed comparison on
    TPU (REPRO_PALLAS_INTERPRET=0).
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    from repro.models import layers as L

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (G, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (G, W, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (G, W, KV, hd), jnp.float32)
    take = jax.random.randint(ks[3], (G,), 1, S + 1).astype(jnp.int32)
    pos0 = jax.random.randint(ks[4], (G,), 0, W + 1 - take).astype(jnp.int32)
    n_tok = int(np.asarray(take).sum())

    ref_fn = jax.jit(lambda q, k, v, p, t: L.ragged_prefill_attention(
        q, k, v, pos0=p, take=t))

    def timed(fn):
        fn(q, k, v, pos0, take).block_until_ready()      # warm-up/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(q, k, v, pos0, take)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    rows = []
    for mode, fn in (("prefill-ref", ref_fn),
                     ("prefill-pallas", ops.ragged_prefill_attention)):
        dt = timed(fn)
        rows.append({"mode": mode, "G": G, "S": S, "kv_width": W,
                     "heads": H, "kv_heads": KV, "head_dim": hd,
                     "ms_per_call": dt * 1e3,
                     "prefill_tok_per_s": n_tok / dt if dt > 0 else 0.0})
    return rows


def run_decode_microbench(*, B=8, M=256, H=4, KV=2, hd=64, iters=10):
    """Ref-vs-kernel batched decode-attention microbench.

    Times the exact op ``_dispatch_attention`` routes per decode tick —
    the jnp reference (``decode_attention_ref``) vs the batched Pallas
    decode kernel (one (B, M/bk) launch for all slots) — on an
    engine-shaped workload (B slots, ragged per-slot ``kv_len`` over an
    M-line cache). Same caveat as the prefill microbench: interpret mode
    on CPU, a real speed comparison on TPU.
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops, ref

    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q = jax.random.normal(ks[0], (B, 1, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, M, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, M, KV, hd), jnp.float32)
    kv_len = jax.random.randint(ks[3], (B,), 1, M + 1).astype(jnp.int32)

    ref_fn = jax.jit(lambda q, k, v, n: ref.decode_attention_ref(q, k, v, n))
    ker_fn = jax.jit(lambda q, k, v, n: ops.decode_attention(
        q, k, v, kv_len=n))

    def timed(fn):
        fn(q, k, v, kv_len).block_until_ready()          # warm-up/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(q, k, v, kv_len)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    rows = []
    for mode, fn in (("decode-ref", ref_fn), ("decode-pallas", ker_fn)):
        dt = timed(fn)
        rows.append({"mode": mode, "B": B, "cache_len": M,
                     "heads": H, "kv_heads": KV, "head_dim": hd,
                     "ms_per_call": dt * 1e3,
                     "decode_tok_per_s": B / dt if dt > 0 else 0.0})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=None,
                    help="analytic-section query count")
    ap.add_argument("--real-queries", type=int, default=6,
                    help="real-engine-section query count (0 disables)")
    ap.add_argument("--pool-queries", type=int, default=12,
                    help="pooled-vs-single cloud section query count "
                         "(0 disables; needs to be deep enough to keep "
                         "every replica's slots leased)")
    ap.add_argument("--pool-replicas", type=int, default=2,
                    help="cloud pool replicas for the pooled section")
    ap.add_argument("--degraded-queries", type=int, default=12,
                    help="chaos-overhead section query count: clean vs "
                         "10%% injected cloud faults + a replica crash "
                         "(0 disables)")
    ap.add_argument("--openloop-replicas", type=int, default=4,
                    help="elastic cloud pool ceiling for the open-loop "
                         "trace-replay section (0 disables)")
    ap.add_argument("--prefix-queries", type=int, default=6,
                    help="KV prefix-reuse fidelity section query count "
                         "(deterministic, gates in CI; 0 disables)")
    ap.add_argument("--prefix-fleet", type=int, default=0,
                    help="heavy live-runtime prefix-reuse fleet query "
                         "count (nightly; 0 disables)")
    ap.add_argument("--benchmark", default="gpqa")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--prefill-iters", type=int, default=3,
                    help="ref-vs-kernel prefill microbench iterations "
                         "(0 disables)")
    ap.add_argument("--decode-iters", type=int, default=10,
                    help="ref-vs-kernel decode microbench iterations "
                         "(0 disables)")
    args = ap.parse_args()

    header, rows = run(args.queries, args.benchmark)
    C.print_csv("serve_throughput", header, rows)
    seq_qps = rows[0][4]
    best = max(rows[1:], key=lambda r: r[4])
    print(f"\nbest: {best[0]} at {best[4]:.3f} q/s "
          f"({best[4] / seq_qps:.2f}x sequential)")

    json_rows = [dict(zip(["mode", "max_inflight", "queries", "makespan_s",
                           "qps", "p50", "p99", "accuracy", "api_usd"], r),
                      prefill_tokens=None, peak_active=None) for r in rows]

    tg_rows = run_trace_gen()
    C.print_csv("serve_trace_gen", list(tg_rows[0].keys()),
                [list(r.values()) for r in tg_rows])
    print(f"\ntrace generator: {tg_rows[0]['measured_rps']:.3f} rps "
          f"measured vs {tg_rows[0]['target_rps']:.3f} target "
          f"({tg_rows[0]['rps_err_pct']:.2f}% err; CI gates at 5%)")
    json_rows += tg_rows

    if args.real_queries > 0:
        real_rows, speedup = run_real(args.real_queries, args.benchmark)
        C.print_csv("serve_throughput_real",
                    list(real_rows[0].keys()),
                    [list(r.values()) for r in real_rows])
        print(f"\nreal-engine pump speedup: {speedup:.2f}x wall-clock over "
              f"synchronous dispatch (target >= {MIN_REAL_SPEEDUP}x)")
        if speedup < MIN_REAL_SPEEDUP:
            print(f"WARNING: pump speedup {speedup:.2f}x below "
                  f"{MIN_REAL_SPEEDUP}x target")
        json_rows += real_rows

    if args.pool_queries > 0:
        pool_rows, pspeed = run_pool(args.pool_queries, args.benchmark,
                                     replicas=args.pool_replicas)
        C.print_csv("serve_cloud_pool",
                    list(pool_rows[0].keys()),
                    [list(r.values()) for r in pool_rows])
        print(f"\npooled-cloud speedup: {pspeed:.2f}x wall-clock over the "
              f"single cloud engine (R={args.pool_replicas}, "
              f"capacity {pool_rows[1]['cloud_capacity']} vs "
              f"{pool_rows[0]['cloud_capacity']})")
        if pspeed < 1.0:
            print(f"WARNING: pooled cloud did not beat the single engine "
                  f"({pspeed:.2f}x)")
        json_rows += pool_rows

    if args.degraded_queries > 0:
        deg_rows, overhead = run_degraded(args.degraded_queries,
                                          args.benchmark)
        C.print_csv("serve_degraded",
                    [k for k in deg_rows[1].keys()],
                    [[r.get(k) for k in deg_rows[1].keys()]
                     for r in deg_rows])
        print(f"\nchaos overhead: {overhead:+.1f}% wall-clock to absorb "
              f"{deg_rows[1]['injected_submit_faults']} injected faults "
              f"+ {deg_rows[1]['deaths']} replica death(s) "
              f"({deg_rows[1]['retries']} retries, "
              f"{deg_rows[1]['degraded']} degraded, "
              f"{deg_rows[1]['failovers']} failovers) — all "
              f"{deg_rows[1]['queries']} queries completed")
        json_rows += deg_rows

    if args.openloop_replicas > 0:
        ol_rows = run_openloop(args.benchmark,
                               replicas=args.openloop_replicas)
        C.print_csv("serve_openloop", list(ol_rows[0].keys()),
                    [list(r.values()) for r in ol_rows])
        r = ol_rows[0]
        print(f"\nopen loop: {r['queries']} queries at "
              f"{r['offered_rps']:.2f} rps offered — ttft p50 "
              f"{r['ttft_p50']:.2f}s p99 {r['ttft_p99']:.2f}s | autoscale "
              f"ups={r['scale_ups']} downs={r['scale_downs']} "
              f"to_zero={r['scale_to_zero']} pokes={r['pokes']}")
        json_rows += ol_rows

    if args.prefix_queries > 0:
        px_rows, px_save = run_prefix(args.prefix_queries)
        C.print_csv("serve_prefix", list(px_rows[0].keys()),
                    [list(r.values()) for r in px_rows])
        print(f"\nprefix reuse: {px_save:.1f}% of prefill tokens skipped "
              f"({px_rows[1]['prefix_hits']} hits, "
              f"{px_rows[1]['prefix_copies']} cross-slot copies) with "
              f"bit-identical greedy outputs — CI gates savings >= 40%")
        json_rows += px_rows

    if args.prefix_fleet > 0:
        pxf_rows, pxf_save = run_prefix_fleet(args.prefix_fleet,
                                              args.benchmark)
        C.print_csv("serve_prefix_fleet", list(pxf_rows[0].keys()),
                    [list(r.values()) for r in pxf_rows])
        print(f"\nprefix reuse (live fleet): {pxf_save:.1f}% prefill "
              f"tokens skipped; wall {pxf_rows[0]['wall_s']:.2f}s off -> "
              f"{pxf_rows[1]['wall_s']:.2f}s on, same answers")
        json_rows += pxf_rows

    if args.prefill_iters > 0:
        pf_rows = run_prefill_microbench(iters=args.prefill_iters)
        C.print_csv("serve_prefill_microbench",
                    list(pf_rows[0].keys()),
                    [list(r.values()) for r in pf_rows])
        json_rows += pf_rows

    if args.decode_iters > 0:
        dec_rows = run_decode_microbench(iters=args.decode_iters)
        C.print_csv("serve_decode_microbench",
                    list(dec_rows[0].keys()),
                    [list(r.values()) for r in dec_rows])
        json_rows += dec_rows

    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_rows, f, indent=2)
        print(f"wrote {args.json} ({len(json_rows)} rows)")


if __name__ == "__main__":
    main()
