"""Fleet serving throughput: concurrent multi-query runtime vs the seed's
sequential one-query-at-a-time loop.

For each in-flight level the same query stream runs through the
HybridFlow scheduler twice — once admitted all together (bounded by
``max_inflight``), once back-to-back — and we report queries per
simulated second, p50/p99 per-query makespan, accuracy and API cost.
The concurrent runtime must beat the sequential baseline on qps at
every in-flight level >= 2 (pool overlap across queries is the whole
point of fleet scheduling).

``PYTHONPATH=src python -m benchmarks.serve_throughput [--queries N]``
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C
from repro.core.hybridflow import HybridFlowPolicy
from repro.serving.runtime import ServingRuntime

INFLIGHT_LEVELS = (2, 4, 8, 16)


def _runtime(pipe, router, **kw):
    policy = HybridFlowPolicy(router, wm=pipe.wm)
    return ServingRuntime(pipe.edge, pipe.cloud, policy,
                          planner=pipe.planner, **kw)


def run(n_queries=None, bench="gpqa"):
    n = n_queries or max(32, min(C.N_QUERIES, 64))
    pipe = C.shared_pipeline(0)
    router = C.shared_router()
    qs = C.queries(bench, n)

    rows = []
    seq = _runtime(pipe, router).serve_sequential(qs)
    rows.append(["sequential", 1, n, seq.makespan, seq.qps,
                 seq.p50_latency, seq.p99_latency, seq.accuracy,
                 seq.api_cost])
    for m in INFLIGHT_LEVELS:
        rep = _runtime(pipe, router, max_inflight=m).serve(qs)
        rows.append([f"concurrent-{m}", m, n, rep.makespan, rep.qps,
                     rep.p50_latency, rep.p99_latency, rep.accuracy,
                     rep.api_cost])
        assert rep.stats["peak_inflight"] == min(m, n)
        if rep.qps <= seq.qps:
            print(f"WARNING: concurrent-{m} qps {rep.qps:.3f} did not beat "
                  f"sequential {seq.qps:.3f}")
    header = ["mode", "max_inflight", "queries", "makespan_s", "qps",
              "p50_s", "p99_s", "accuracy", "api_usd"]
    return header, rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=None)
    ap.add_argument("--benchmark", default="gpqa")
    args = ap.parse_args()
    header, rows = run(args.queries, args.benchmark)
    C.print_csv("serve_throughput", header, rows)
    seq_qps = rows[0][4]
    best = max(rows[1:], key=lambda r: r[4])
    print(f"\nbest: {best[0]} at {best[4]:.3f} q/s "
          f"({best[4] / seq_qps:.2f}x sequential)")


if __name__ == "__main__":
    main()
