"""Fleet serving throughput: concurrent multi-query runtime vs the seed's
sequential one-query-at-a-time loop — analytic executors AND real JAX
engines.

Analytic section: for each in-flight level the same query stream runs
through the HybridFlow scheduler twice — once admitted all together
(bounded by ``max_inflight``), once back-to-back — reporting queries per
simulated second, p50/p99 per-query makespan, accuracy and API cost.

Real-engine section: the same fleet drives a ``JAXExecutor`` pair
(reduced-config models decoding for real) in two modes —

* ``real-sync``  — the pre-pump synchronous dispatch (``pump=False``):
  each subtask blocks in ``Executor.run`` and drains alone, so engine
  ``peak_active`` stays 1;
* ``real-pump``  — the async pump loop: co-scheduled subtasks decode in
  the same micro-batches via batched chunked prefill + batched decode.

The pump mode must beat the synchronous wall-clock by >= 1.3x (the
overlap is the whole point). A third section microbenches the ragged
chunked-prefill attention op itself — jnp reference twin vs the Pallas
kernel (``prefill-ref`` / ``prefill-pallas`` rows). Results are also
written as machine-readable ``BENCH_serve.json`` rows ``{mode, qps, p50,
p99, prefill_tokens, peak_active, ...}`` for the cross-PR perf
trajectory (diffed against ``benchmarks/baseline_serve.json`` by
``benchmarks/check_bench.py`` in CI).

``PYTHONPATH=src python -m benchmarks.serve_throughput [--queries N]
[--real-queries M] [--json PATH]``
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common as C
from repro.core.hybridflow import HybridFlowPolicy
from repro.serving.runtime import ServingRuntime

INFLIGHT_LEVELS = (2, 4, 8, 16)
MIN_REAL_SPEEDUP = 1.3


def _runtime(pipe, router, **kw):
    policy = HybridFlowPolicy(router, wm=pipe.wm)
    return ServingRuntime(pipe.edge, pipe.cloud, policy,
                          planner=pipe.planner, **kw)


def run(n_queries=None, bench="gpqa"):
    n = n_queries or max(32, min(C.N_QUERIES, 64))
    pipe = C.shared_pipeline(0)
    router = C.shared_router()
    qs = C.queries(bench, n)

    rows = []
    seq = _runtime(pipe, router).serve_sequential(qs)
    rows.append(["sequential", 1, n, seq.makespan, seq.qps,
                 seq.p50_latency, seq.p99_latency, seq.accuracy,
                 seq.api_cost])
    for m in INFLIGHT_LEVELS:
        rep = _runtime(pipe, router, max_inflight=m).serve(qs)
        rows.append([f"concurrent-{m}", m, n, rep.makespan, rep.qps,
                     rep.p50_latency, rep.p99_latency, rep.accuracy,
                     rep.api_cost])
        assert rep.stats["peak_inflight"] == min(m, n)
        if rep.qps <= seq.qps:
            print(f"WARNING: concurrent-{m} qps {rep.qps:.3f} did not beat "
                  f"sequential {seq.qps:.3f}")
    header = ["mode", "max_inflight", "queries", "makespan_s", "qps",
              "p50_s", "p99_s", "accuracy", "api_usd"]
    return header, rows


class _HashRoutePolicy:
    """Deterministic per-node routing (cloud unless sid % 3 == 0): the
    same decisions regardless of completion order, so sync vs pump run
    identical work and the wall-clock comparison is fair."""

    def decide(self, query, node, ctx):
        return int(node.sid % 3 != 0), {}

    def observe(self, query, node, r, result, ctx):
        pass


def run_real(n_queries=6, bench="gpqa", *, arch="qwen2-1.5b",
             max_inflight=8):
    """Real-JAX-engine fleet: synchronous dispatch vs the async pump."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_config
    from repro.core.planner import SyntheticPlanner
    from repro.data.tasks import WorldModel, gen_benchmark
    from repro.models import model as M
    from repro.serving.engine import JAXExecutor, ServingEngine

    cfg = get_config(arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0), dtype=jnp.float32)
    wm = WorldModel()
    qs = gen_benchmark(bench, n_queries)

    def serve(pump: bool):
        edge_e = ServingEngine(cfg, params, batch_slots=2, max_len=160,
                               prefill_chunk=64)
        cloud_e = ServingEngine(cfg, params, batch_slots=4, max_len=160,
                                prefill_chunk=64)
        edge = JAXExecutor(edge_e, wm, cloud=False, concurrency=1)
        cloud = JAXExecutor(cloud_e, wm, cloud=True, concurrency=4,
                            price_out=3.2e-5)
        rt = ServingRuntime(edge, cloud, _HashRoutePolicy(),
                            planner=SyntheticPlanner(),
                            max_inflight=max_inflight, pump=pump)
        rep = rt.serve(qs)
        return rep, edge_e, cloud_e

    # warm-up BOTH modes: each produces its own prefill-group shapes
    # (pump: G>=2 per call; sync: G=1), so jit compiles must be paid
    # outside either timed window for a fair wall-clock comparison
    serve(True)
    serve(False)
    rows = []
    for mode, pump in (("real-sync", False), ("real-pump", True)):
        rep, edge_e, cloud_e = serve(pump)
        rows.append({
            "mode": mode,
            "queries": n_queries,
            "qps": rep.n / rep.wall_s if rep.wall_s > 0 else 0.0,
            "p50": rep.p50_latency,
            "p99": rep.p99_latency,
            "wall_s": rep.wall_s,
            "prefill_tokens": (edge_e.stats["prefill_tokens"]
                               + cloud_e.stats["prefill_tokens"]),
            "peak_active": max(edge_e.stats["peak_active"],
                               cloud_e.stats["peak_active"]),
            "prefill_batch_max": max(edge_e.stats["prefill_batch_max"],
                                     cloud_e.stats["prefill_batch_max"]),
        })
    speedup = rows[0]["wall_s"] / max(rows[1]["wall_s"], 1e-9)
    return rows, speedup


def run_prefill_microbench(*, G=4, S=64, W=256, H=4, KV=2, hd=64, iters=3):
    """Ref-vs-kernel ragged chunked-prefill attention microbench.

    Times the exact op ``serve_prefill_chunk`` dispatches per layer — the
    jnp reference twin vs the Pallas ragged kernel — on one engine-shaped
    workload (G chunk rows, ragged take/pos0, kv_width=W). On CPU the
    kernel runs in interpret mode, so treat these numbers as a
    plumbing/trajectory check; they become a real speed comparison on
    TPU (REPRO_PALLAS_INTERPRET=0).
    """
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels import ops
    from repro.models import layers as L

    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    q = jax.random.normal(ks[0], (G, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (G, W, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (G, W, KV, hd), jnp.float32)
    take = jax.random.randint(ks[3], (G,), 1, S + 1).astype(jnp.int32)
    pos0 = jax.random.randint(ks[4], (G,), 0, W + 1 - take).astype(jnp.int32)
    n_tok = int(np.asarray(take).sum())

    ref_fn = jax.jit(lambda q, k, v, p, t: L.ragged_prefill_attention(
        q, k, v, pos0=p, take=t))

    def timed(fn):
        fn(q, k, v, pos0, take).block_until_ready()      # warm-up/compile
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(q, k, v, pos0, take)
        out.block_until_ready()
        return (time.perf_counter() - t0) / iters

    rows = []
    for mode, fn in (("prefill-ref", ref_fn),
                     ("prefill-pallas", ops.ragged_prefill_attention)):
        dt = timed(fn)
        rows.append({"mode": mode, "G": G, "S": S, "kv_width": W,
                     "heads": H, "kv_heads": KV, "head_dim": hd,
                     "ms_per_call": dt * 1e3,
                     "prefill_tok_per_s": n_tok / dt if dt > 0 else 0.0})
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--queries", type=int, default=None,
                    help="analytic-section query count")
    ap.add_argument("--real-queries", type=int, default=6,
                    help="real-engine-section query count (0 disables)")
    ap.add_argument("--benchmark", default="gpqa")
    ap.add_argument("--json", default="BENCH_serve.json",
                    help="machine-readable output path ('' disables)")
    ap.add_argument("--prefill-iters", type=int, default=3,
                    help="ref-vs-kernel prefill microbench iterations "
                         "(0 disables)")
    args = ap.parse_args()

    header, rows = run(args.queries, args.benchmark)
    C.print_csv("serve_throughput", header, rows)
    seq_qps = rows[0][4]
    best = max(rows[1:], key=lambda r: r[4])
    print(f"\nbest: {best[0]} at {best[4]:.3f} q/s "
          f"({best[4] / seq_qps:.2f}x sequential)")

    json_rows = [dict(zip(["mode", "max_inflight", "queries", "makespan_s",
                           "qps", "p50", "p99", "accuracy", "api_usd"], r),
                      prefill_tokens=None, peak_active=None) for r in rows]

    if args.real_queries > 0:
        real_rows, speedup = run_real(args.real_queries, args.benchmark)
        C.print_csv("serve_throughput_real",
                    list(real_rows[0].keys()),
                    [list(r.values()) for r in real_rows])
        print(f"\nreal-engine pump speedup: {speedup:.2f}x wall-clock over "
              f"synchronous dispatch (target >= {MIN_REAL_SPEEDUP}x)")
        if speedup < MIN_REAL_SPEEDUP:
            print(f"WARNING: pump speedup {speedup:.2f}x below "
                  f"{MIN_REAL_SPEEDUP}x target")
        json_rows += real_rows

    if args.prefill_iters > 0:
        pf_rows = run_prefill_microbench(iters=args.prefill_iters)
        C.print_csv("serve_prefill_microbench",
                    list(pf_rows[0].keys()),
                    [list(r.values()) for r in pf_rows])
        json_rows += pf_rows

    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_rows, f, indent=2)
        print(f"wrote {args.json} ({len(json_rows)} rows)")


if __name__ == "__main__":
    main()
