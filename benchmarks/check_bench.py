"""Cross-PR serve-bench regression check.

Diffs a freshly produced ``BENCH_serve.json`` against the committed
``benchmarks/baseline_serve.json`` and exits non-zero when any gating
mode regresses beyond tolerance — qps for the scheduler/runtime rows,
``prefill_tok_per_s`` / ``decode_tok_per_s`` for the kernel-microbench
rows.

Rows come in four classes; ``--only`` selects analytic vs everything
measured on a wall clock:

* **analytic** — simulated-clock scheduler/runtime rows (``sequential``,
  ``concurrent-*``) plus the ``trace-gen`` arrival-generator fidelity
  row. Deterministic up to scheduler tie-breaks, so their metric diff
  GATES CI (a drop beyond ``--tolerance``, default 20%, fails the job
  on any machine). On top of the baseline diff, the current run's
  ``trace-gen`` row must show the Poisson generator's measured mean RPS
  within 5% of its target — a miss there is generator breakage, not
  noise.
* **microbench** — ``prefill-*`` / ``decode-*`` kernel rows. Single-op
  timings are far less noisy than full fleet runs, so these GATE too,
  at the looser ``--real-tolerance`` (default 60%). On top of the
  baseline diff, the current run itself must show the Pallas prefill
  kernel no slower than its jnp reference row (``prefill-pallas``
  ms_per_call <= ``prefill-ref``) — the regression this gate exists to
  catch; the decode pair prints a warning when the kernel loses.
* **prefix-reuse** — the ``prefix-reuse-off`` / ``prefix-reuse-on``
  KV-reuse fidelity rows. Their metrics (``savings_pct``, token
  counters) are pure functions of the prompt set, so they ride the
  analytic (gating) step: the on-row's ``savings_pct`` diffs against
  baseline at the analytic tolerance, and two cross-row gates inside
  the current run are exact — reuse-on must not decode more tokens than
  reuse-off, and must keep skipping >= 40% of the no-reuse prefill
  work. (The live-fleet twins ``real-prefix-*`` are nightly, warn-only
  real rows and stay out of the committed baseline.)
* **real** — ``real-*`` fleet rows measured on whatever shared runner
  ran them. Too noisy to gate: a regression prints a WARNING in the log
  without failing the job, so the step no longer needs
  ``continue-on-error``. The chaos rows (``real-faultfree`` /
  ``real-degraded``) and the open-loop elastic row (``real-openloop``)
  ride this class by construction — their prefix makes them warn-only,
  while each section's own in-run invariants (every query completes
  under faults; scale-to-zero + poke-to-warm fire during the trace
  replay) still hard-fail inside ``serve_throughput`` itself.

``PYTHONPATH=src python -m benchmarks.check_bench [--current PATH]
[--baseline PATH] [--only analytic|wallclock] [--tolerance 0.2]
[--real-tolerance 0.6]``

Refresh the baseline by committing a new ``benchmarks/baseline_serve.json``
produced by ``benchmarks.serve_throughput`` with the CI arguments
(``--queries 8 --real-queries 3``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _load(path):
    with open(path) as f:
        rows = json.load(f)
    return {r["mode"]: r for r in rows if isinstance(r, dict) and "mode" in r}


def _metric(row):
    """(name, value) of the row's throughput metric, or (None, None).

    ``savings_pct`` serves the prefix-reuse-on row: it is a pure
    function of the prompt set (no clock anywhere), so it diffs at the
    analytic tolerance. The reuse-off row has no positive metric and is
    deliberately skipped — its job is the cross-row gates below."""
    for name in ("qps", "prefill_tok_per_s", "decode_tok_per_s",
                 "measured_rps", "savings_pct"):
        v = row.get(name)
        if isinstance(v, (int, float)) and v > 0:
            return name, float(v)
    return None, None


def _row_class(mode: str) -> str:
    if mode.startswith(("prefill-", "decode-")):
        return "microbench"
    if mode.startswith("real-"):
        return "real"
    if mode.startswith("prefix-reuse"):
        return "prefix-reuse"
    return "analytic"


def _is_wallclock(mode: str) -> bool:
    # prefix-reuse rows carry deterministic token-count metrics, so they
    # ride the analytic (gating) step even though the section also
    # records a wall_s for the log
    return _row_class(mode) not in ("analytic", "prefix-reuse")


def _kernel_vs_ref(cur, pallas_mode, ref_mode):
    """(pallas_ms, ref_ms) from the current run, or None if either row
    (or its ms_per_call) is absent."""
    p, r = cur.get(pallas_mode), cur.get(ref_mode)
    if not p or not r:
        return None
    pm, rm = p.get("ms_per_call"), r.get("ms_per_call")
    if not isinstance(pm, (int, float)) or not isinstance(rm, (int, float)):
        return None
    return float(pm), float(rm)


def check(current: str, baseline: str, tolerance: float,
          real_tolerance: float, only: str = None) -> int:
    if not os.path.exists(baseline):
        print(f"no baseline at {baseline}; nothing to compare")
        return 0
    if not os.path.exists(current):
        print(f"ERROR: current bench file {current} not found "
              f"(did the smoke run fail?)")
        return 1
    cur = _load(current)
    base = _load(baseline)
    selected = cur
    if only is not None:
        want = _is_wallclock if only == "wallclock" \
            else (lambda m: not _is_wallclock(m))
        base = {m: r for m, r in base.items() if want(m)}
        selected = {m: r for m, r in cur.items() if want(m)}

    regressions = []
    warnings = []
    compared = 0
    print(f"{'mode':<24} {'metric':<18} {'baseline':>12} {'current':>12} "
          f"{'delta':>8}")
    for mode, brow in sorted(base.items()):
        name, bval = _metric(brow)
        crow = selected.get(mode)
        if name is None or crow is None:
            continue
        cval = crow.get(name)
        if not isinstance(cval, (int, float)):
            continue
        compared += 1
        delta = (cval - bval) / bval
        cls = _row_class(mode)
        tol = tolerance if cls in ("analytic", "prefix-reuse") \
            else real_tolerance
        bad = delta < -tol
        flag = ""
        if bad and cls == "real":
            flag = " <-- WARNING (non-gating real-engine row)"
            warnings.append((mode, name, bval, cval, delta))
        elif bad:
            flag = " <-- REGRESSION"
            regressions.append((mode, name, bval, cval, delta))
        print(f"{mode:<24} {name:<18} {bval:>12.3f} {cval:>12.3f} "
              f"{delta:>7.1%}{flag}")

    # cross-row gate inside the CURRENT run: the Pallas prefill kernel
    # must not lose to the jnp reference it replaces (this is the exact
    # regression shape the microbench class exists to catch)
    if only != "analytic":
        pf = _kernel_vs_ref(selected, "prefill-pallas", "prefill-ref")
        if pf is not None:
            pm, rm = pf
            verdict = "OK" if pm <= rm else "FAIL"
            print(f"\nprefill kernel vs ref: pallas {pm:.3f} ms/call, "
                  f"ref {rm:.3f} ms/call ({verdict})")
            if pm > rm:
                regressions.append(("prefill-pallas>ref", "ms_per_call",
                                    rm, pm, (pm - rm) / rm))
        dec = _kernel_vs_ref(selected, "decode-pallas", "decode-ref")
        if dec is not None and dec[0] > dec[1]:
            print(f"WARNING: decode-pallas {dec[0]:.3f} ms/call slower "
                  f"than decode-ref {dec[1]:.3f} ms/call")
            warnings.append(("decode-pallas>ref", "ms_per_call",
                             dec[1], dec[0], (dec[0] - dec[1]) / dec[1]))

    # cross-row gate inside the CURRENT run, analytic side: the Poisson
    # arrival generator must hit its target rate within 5% — the row is
    # deterministic host arithmetic, so a miss is breakage, not noise
    if only != "wallclock":
        tg = selected.get("trace-gen")
        if tg is not None:
            t, m = tg.get("target_rps"), tg.get("measured_rps")
            if isinstance(t, (int, float)) and t > 0 \
                    and isinstance(m, (int, float)):
                err = abs(m - t) / t
                verdict = "OK" if err <= 0.05 else "FAIL"
                print(f"\ntrace generator vs target: {m:.3f} rps measured, "
                      f"{t:.3f} rps target ({err:.1%}, {verdict})")
                if err > 0.05:
                    regressions.append(("trace-gen!=target", "measured_rps",
                                        t, m, err))

    # cross-row gates inside the CURRENT run, prefix-reuse side: both
    # token counters are deterministic, so these are exact invariants,
    # not tolerance diffs. Reuse must (a) never change what gets decoded
    # (same tokens out — the bit-identity contract's cheap observable)
    # and (b) keep skipping at least 40% of the no-reuse prefill work on
    # the shared-prefix fleet.
    if only != "wallclock":
        on, off = selected.get("prefix-reuse-on"), \
            selected.get("prefix-reuse-off")
        if on is not None and off is not None:
            t_on, t_off = on.get("tokens_out"), off.get("tokens_out")
            if isinstance(t_on, (int, float)) \
                    and isinstance(t_off, (int, float)):
                verdict = "OK" if t_on <= t_off else "FAIL"
                print(f"\nprefix reuse tokens out: on {t_on:.0f} vs "
                      f"off {t_off:.0f} ({verdict})")
                if t_on > t_off:
                    regressions.append(("prefix-on>off-tokens",
                                        "tokens_out", t_off, t_on,
                                        (t_on - t_off) / max(t_off, 1)))
            sp = on.get("savings_pct")
            if isinstance(sp, (int, float)):
                verdict = "OK" if sp >= 40.0 else "FAIL"
                print(f"prefix reuse savings: {sp:.1f}% of prefill "
                      f"tokens skipped (floor 40%, {verdict})")
                if sp < 40.0:
                    regressions.append(("prefix-savings<40%",
                                        "savings_pct", 40.0, sp,
                                        (sp - 40.0) / 40.0))

    # a gate that compares nothing gates nothing: renamed/dropped modes
    # must fail loudly instead of silently passing the check
    missing = sorted(set(base) - set(selected))
    if base and compared == 0:
        print(f"\nFAIL: baseline has {len(base)} mode(s) but none were "
              f"comparable in the current run (renamed modes?)")
        return 1
    if missing:
        if only is not None:
            print(f"\nFAIL: --only {only} baseline modes absent from the "
                  f"current run: {missing}")
            return 1
        print(f"note: modes in baseline but not in current run: {missing}")
    if warnings:
        print(f"\nnote: {len(warnings)} non-gating warning(s) above")
    if regressions:
        print(f"\nFAIL: {len(regressions)} gating check(s) failed "
              f"(analytic tol {tolerance:.0%} / microbench tol "
              f"{real_tolerance:.0%} / kernel-vs-ref)")
        return 1
    print("\nOK: no gating serve-bench regression")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_serve.json")
    ap.add_argument("--baseline", default=os.path.join(
        os.path.dirname(__file__), "baseline_serve.json"))
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional drop for analytic rows")
    ap.add_argument("--real-tolerance", type=float, default=0.6,
                    help="allowed fractional drop for wall-clock rows "
                         "(gating prefill-*/decode-* microbench rows; "
                         "real-* engine rows only warn)")
    ap.add_argument("--only", choices=["analytic", "wallclock"],
                    default=None,
                    help="restrict the diff to one row class (CI runs "
                         "analytic and wallclock as separate steps; "
                         "wallclock = microbench gates + real-* warnings)")
    args = ap.parse_args()
    sys.exit(check(args.current, args.baseline, args.tolerance,
                   args.real_tolerance, args.only))


if __name__ == "__main__":
    main()
