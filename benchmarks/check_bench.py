"""Cross-PR serve-bench regression check.

Diffs a freshly produced ``BENCH_serve.json`` against the committed
``benchmarks/baseline_serve.json`` and exits non-zero when any comparable
mode regresses beyond tolerance — qps for the scheduler/runtime rows,
``prefill_tok_per_s`` for the prefill-microbench rows.

Rows come in two classes, selectable with ``--only``:

* **analytic** — simulated-clock scheduler/runtime rows (``sequential``,
  ``concurrent-*``). Deterministic up to scheduler tie-breaks, so their
  qps diff GATES CI (a drop beyond ``--tolerance``, default 20%, fails
  the job on any machine).
* **wallclock** — ``real-*`` and ``prefill-*`` rows measured on whatever
  machine ran them. CI checks these with ``continue-on-error: true``
  (shared runners are noisy) and the looser ``--real-tolerance``
  (default 60%): a regression fails loudly in the log/annotations
  without gating the PR.

``PYTHONPATH=src python -m benchmarks.check_bench [--current PATH]
[--baseline PATH] [--only analytic|wallclock] [--tolerance 0.2]
[--real-tolerance 0.6]``

Refresh the baseline by committing a new ``benchmarks/baseline_serve.json``
produced by ``benchmarks.serve_throughput`` with the CI arguments
(``--queries 8 --real-queries 3``).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def _load(path):
    with open(path) as f:
        rows = json.load(f)
    return {r["mode"]: r for r in rows if isinstance(r, dict) and "mode" in r}


def _metric(row):
    """(name, value) of the row's throughput metric, or (None, None)."""
    for name in ("qps", "prefill_tok_per_s"):
        v = row.get(name)
        if isinstance(v, (int, float)) and v > 0:
            return name, float(v)
    return None, None


def _is_wallclock(mode: str) -> bool:
    return mode.startswith(("real-", "prefill-"))


def check(current: str, baseline: str, tolerance: float,
          real_tolerance: float, only: str = None) -> int:
    if not os.path.exists(baseline):
        print(f"no baseline at {baseline}; nothing to compare")
        return 0
    if not os.path.exists(current):
        print(f"ERROR: current bench file {current} not found "
              f"(did the smoke run fail?)")
        return 1
    cur = _load(current)
    base = _load(baseline)
    if only is not None:
        want = (lambda m: _is_wallclock(m)) if only == "wallclock" \
            else (lambda m: not _is_wallclock(m))
        base = {m: r for m, r in base.items() if want(m)}
        cur = {m: r for m, r in cur.items() if want(m)}

    regressions = []
    compared = 0
    print(f"{'mode':<24} {'metric':<18} {'baseline':>12} {'current':>12} "
          f"{'delta':>8}")
    for mode, brow in sorted(base.items()):
        name, bval = _metric(brow)
        crow = cur.get(mode)
        if name is None or crow is None:
            continue
        cval = crow.get(name)
        if not isinstance(cval, (int, float)):
            continue
        compared += 1
        delta = (cval - bval) / bval
        tol = real_tolerance if _is_wallclock(mode) else tolerance
        flag = " <-- REGRESSION" if delta < -tol else ""
        print(f"{mode:<24} {name:<18} {bval:>12.3f} {cval:>12.3f} "
              f"{delta:>7.1%}{flag}")
        if flag:
            regressions.append((mode, name, bval, cval, delta))

    # a gate that compares nothing gates nothing: renamed/dropped modes
    # must fail loudly instead of silently passing the check
    missing = sorted(set(base) - set(cur))
    if base and compared == 0:
        print(f"\nFAIL: baseline has {len(base)} mode(s) but none were "
              f"comparable in the current run (renamed modes?)")
        return 1
    if missing:
        if only is not None:
            print(f"\nFAIL: --only {only} baseline modes absent from the "
                  f"current run: {missing}")
            return 1
        print(f"note: modes in baseline but not in current run: {missing}")
    if regressions:
        print(f"\nFAIL: {len(regressions)} mode(s) regressed beyond "
              f"tolerance (analytic {tolerance:.0%} / wall-clock "
              f"{real_tolerance:.0%})")
        return 1
    print("\nOK: no serve-bench regression beyond tolerance")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_serve.json")
    ap.add_argument("--baseline", default=os.path.join(
        os.path.dirname(__file__), "baseline_serve.json"))
    ap.add_argument("--tolerance", type=float, default=0.2,
                    help="allowed fractional drop for analytic rows")
    ap.add_argument("--real-tolerance", type=float, default=0.6,
                    help="allowed fractional drop for wall-clock rows "
                         "(real-* engine modes, prefill-* microbench)")
    ap.add_argument("--only", choices=["analytic", "wallclock"],
                    default=None,
                    help="restrict the diff to one row class (CI gates "
                         "analytic, warns on wallclock)")
    args = ap.parse_args()
    sys.exit(check(args.current, args.baseline, args.tolerance,
                   args.real_tolerance, args.only))


if __name__ == "__main__":
    main()
