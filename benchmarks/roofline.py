"""Roofline analysis (deliverable g): reads launch/dryrun.py JSON records
and derives the three per-(arch x shape x mesh) roofline terms:

  compute_s    = HLO_FLOPs_per_chip / peak_FLOP/s        (197 TF bf16, v5e)
  memory_s     = HLO_bytes_per_chip / HBM_bw             (819 GB/s)
  collective_s = collective_bytes_per_chip / link_bw     (50 GB/s/link)

FLOPs/bytes/collectives use the depth-extrapolated values (XLA counts scan
bodies once — see dryrun._depth_variants); post-SPMD HLO shapes are
per-chip, so no further division by chip count is needed. MODEL_FLOPS
ratio flags recompute/redundancy waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks import common as C
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

DRYRUN_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "artifacts", "dryrun"))

_ADVICE = {
    "compute": "increase arithmetic efficiency: larger per-chip tiles, "
               "bf16 everywhere, fuse elementwise chains into matmuls",
    "memory": "cut HBM traffic: flash/blocked attention instead of "
              "materialized scores, fewer remat passes, fused norms",
    "collective": "re-shard: move the dominant collective off the critical "
                  "path (overlap), or change axis mapping to shrink "
                  "all-gather/all-to-all volume",
}


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Optional[List]:
    if rec.get("status") != "ok":
        return None
    cost = rec.get("cost_extrapolated") or rec["cost"]
    coll = rec.get("collectives_extrapolated") or rec["collectives"]
    flops = cost["flops"]
    byts = cost["bytes_accessed"]
    cbytes = coll["total"]
    t_c = flops / PEAK_FLOPS_BF16
    t_m = byts / HBM_BW
    t_x = cbytes / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    model = rec["model_flops"] / rec["n_devices"]
    useful = model / flops if flops else 0.0
    hbm_gib = rec["memory"]["total_bytes"] / 2**30
    return [rec["arch"], rec["shape"], rec["mesh"], t_c, t_m, t_x, dom,
            useful, hbm_gib, _ADVICE[dom]]


def run(dryrun_dir: str = DRYRUN_DIR):
    """Roofline terms are single-pod only (the multi-pod records prove the
    pod axis shards — they are compiled without depth extrapolation, so
    their raw per-body costs are not comparable)."""
    rows = []
    skipped = []
    multi_ok = multi_total = 0
    for rec in load_records(dryrun_dir):
        if rec.get("mesh") == "multi":
            multi_total += 1
            if rec.get("status") in ("ok", "skipped"):
                multi_ok += 1
            continue
        if rec.get("status") == "skipped":
            skipped.append([rec["arch"], rec["shape"], rec["mesh"],
                            "-", "-", "-", "skipped", "-", "-",
                            rec.get("reason", "")])
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
        elif rec.get("status") == "error":
            rows.append([rec["arch"], rec["shape"], rec["mesh"],
                         "-", "-", "-", "ERROR", "-", "-",
                         rec.get("error", "")[:60]])
    rows.extend(skipped)
    if multi_total:
        rows.append(["ALL", "ALL", "multi(2x16x16)", "-", "-", "-",
                     f"{multi_ok}/{multi_total} lower+compile OK", "-", "-",
                     "pod-axis sharding proof (see §Dry-run)"])
    header = ["arch", "shape", "mesh", "compute_s", "memory_s",
              "collective_s", "bottleneck", "useful_flops_ratio",
              "hbm_gib_per_chip", "note"]
    return header, rows


def main():
    header, rows = run()
    C.print_csv("roofline", header, rows)


if __name__ == "__main__":
    main()
