"""Roofline analysis (deliverable g): reads launch/dryrun.py JSON records
and derives the three per-(arch x shape x mesh) roofline terms:

  compute_s    = HLO_FLOPs_per_chip / peak_FLOP/s        (197 TF bf16, v5e)
  memory_s     = HLO_bytes_per_chip / HBM_bw             (819 GB/s)
  collective_s = collective_bytes_per_chip / link_bw     (50 GB/s/link)

FLOPs/bytes/collectives use the depth-extrapolated values (XLA counts scan
bodies once — see dryrun._depth_variants); post-SPMD HLO shapes are
per-chip, so no further division by chip count is needed. MODEL_FLOPS
ratio flags recompute/redundancy waste.

A second section reports achieved-vs-peak for the two serving Pallas
kernels (ragged chunked-prefill attention, batched decode attention):
analytic FLOPs/bytes for the microbench shapes divided by the measured
ms_per_call from ``BENCH_serve.json`` (or a fresh microbench run when
the file is absent), against the v5e peak FLOP/s and HBM bandwidth. On
the CPU CI runner the kernels run in interpret mode so the fractions
are tiny — the section tracks the trajectory and becomes a real
utilization number on TPU.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks import common as C
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW

DRYRUN_DIR = os.environ.get(
    "REPRO_DRYRUN_DIR",
    os.path.join(os.path.dirname(__file__), "artifacts", "dryrun"))

_ADVICE = {
    "compute": "increase arithmetic efficiency: larger per-chip tiles, "
               "bf16 everywhere, fuse elementwise chains into matmuls",
    "memory": "cut HBM traffic: flash/blocked attention instead of "
              "materialized scores, fewer remat passes, fused norms",
    "collective": "re-shard: move the dominant collective off the critical "
                  "path (overlap), or change axis mapping to shrink "
                  "all-gather/all-to-all volume",
}


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: Dict) -> Optional[List]:
    if rec.get("status") != "ok":
        return None
    cost = rec.get("cost_extrapolated") or rec["cost"]
    coll = rec.get("collectives_extrapolated") or rec["collectives"]
    flops = cost["flops"]
    byts = cost["bytes_accessed"]
    cbytes = coll["total"]
    t_c = flops / PEAK_FLOPS_BF16
    t_m = byts / HBM_BW
    t_x = cbytes / ICI_BW
    dom = max(("compute", t_c), ("memory", t_m), ("collective", t_x),
              key=lambda kv: kv[1])[0]
    model = rec["model_flops"] / rec["n_devices"]
    useful = model / flops if flops else 0.0
    hbm_gib = rec["memory"]["total_bytes"] / 2**30
    return [rec["arch"], rec["shape"], rec["mesh"], t_c, t_m, t_x, dom,
            useful, hbm_gib, _ADVICE[dom]]


def run(dryrun_dir: str = DRYRUN_DIR):
    """Roofline terms are single-pod only (the multi-pod records prove the
    pod axis shards — they are compiled without depth extrapolation, so
    their raw per-body costs are not comparable)."""
    rows = []
    skipped = []
    multi_ok = multi_total = 0
    for rec in load_records(dryrun_dir):
        if rec.get("mesh") == "multi":
            multi_total += 1
            if rec.get("status") in ("ok", "skipped"):
                multi_ok += 1
            continue
        if rec.get("status") == "skipped":
            skipped.append([rec["arch"], rec["shape"], rec["mesh"],
                            "-", "-", "-", "skipped", "-", "-",
                            rec.get("reason", "")])
            continue
        row = roofline_row(rec)
        if row:
            rows.append(row)
        elif rec.get("status") == "error":
            rows.append([rec["arch"], rec["shape"], rec["mesh"],
                         "-", "-", "-", "ERROR", "-", "-",
                         rec.get("error", "")[:60]])
    rows.extend(skipped)
    if multi_total:
        rows.append(["ALL", "ALL", "multi(2x16x16)", "-", "-", "-",
                     f"{multi_ok}/{multi_total} lower+compile OK", "-", "-",
                     "pod-axis sharding proof (see §Dry-run)"])
    header = ["arch", "shape", "mesh", "compute_s", "memory_s",
              "collective_s", "bottleneck", "useful_flops_ratio",
              "hbm_gib_per_chip", "note"]
    return header, rows


def _kernel_cost(row: Dict) -> Optional[Dict]:
    """Analytic (flops, bytes) for one serving-kernel microbench row.

    Dense upper bound: raggedness (per-row take/kv_len) and masked-block
    skips only reduce the real work, so achieved-vs-peak from these
    counts is conservative. f32 operands (the microbench dtype).
    """
    H, hd = row.get("heads"), row.get("head_dim")
    KV = row.get("kv_heads")
    if not all(isinstance(x, (int, float)) for x in (H, hd, KV)):
        return None
    if row["mode"].startswith("prefill-"):
        G, S, W = row["G"], row["S"], row["kv_width"]
        flops = 4 * G * H * S * W * hd            # qk + pv matmuls
        byts = 4 * (2 * G * S * H * hd + 2 * G * W * KV * hd)
    elif row["mode"].startswith("decode-"):
        B, M = row["B"], row["cache_len"]
        flops = 4 * B * H * M * hd
        byts = 4 * (2 * B * H * hd + 2 * B * M * KV * hd)
    else:
        return None
    return {"flops": flops, "bytes": byts}


def kernel_rows(serve_json: Optional[str] = None):
    """Achieved-vs-peak rows for the Pallas serving kernels."""
    rows = []
    micro = []
    if serve_json and os.path.exists(serve_json):
        with open(serve_json) as f:
            micro = [r for r in json.load(f)
                     if isinstance(r, dict)
                     and r.get("mode", "").endswith("-pallas")]
    if not micro:
        from benchmarks.serve_throughput import (run_decode_microbench,
                                                 run_prefill_microbench)
        micro = [r for r in run_prefill_microbench() +
                 run_decode_microbench() if r["mode"].endswith("-pallas")]
    for r in micro:
        cost = _kernel_cost(r)
        ms = r.get("ms_per_call")
        if cost is None or not isinstance(ms, (int, float)) or ms <= 0:
            continue
        t = ms / 1e3
        af, ab = cost["flops"] / t, cost["bytes"] / t
        t_c = cost["flops"] / PEAK_FLOPS_BF16
        t_m = cost["bytes"] / HBM_BW
        bound = "memory" if t_m >= t_c else "compute"
        rows.append([r["mode"], ms, af / 1e12, ab / 2**30,
                     af / PEAK_FLOPS_BF16, ab / HBM_BW, bound])
    header = ["kernel", "ms_per_call", "achieved_tflops", "achieved_gibps",
              "pct_peak_flops", "pct_peak_hbm", "roofline_bound"]
    return header, rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--serve-json", default="BENCH_serve.json",
                    help="microbench timings source for the kernel "
                         "section (re-times the kernels when absent)")
    ap.add_argument("--no-kernels", action="store_true",
                    help="skip the serving-kernel achieved-vs-peak "
                         "section (it imports jax)")
    args = ap.parse_args()
    header, rows = run()
    C.print_csv("roofline", header, rows)
    if not args.no_kernels:
        kheader, krows = kernel_rows(args.serve_json)
        if krows:
            C.print_csv("roofline_kernels", kheader, krows)
        else:
            print("roofline_kernels: no Pallas microbench rows found")


if __name__ == "__main__":
    main()
